//! Shared figure-regeneration logic: every `rust/benches/figN.rs` target
//! is a thin main() around one of these runners, so the same code also
//! backs integration tests and the CLI.
//!
//! Every sweep cell runs through the batch engine
//! ([`BatchRequest::execute_on`]): the seeded repetitions of one
//! `(spec, k)` point become the variants of one batch, fanned over the
//! ambient pool lanes with recycled DFEP state — same reports as the old
//! sequential loop (the engine is bit-identical to it; see
//! `tests/batch.rs`), a fraction of the wall clock.
//!
//! Each runner emits a `BENCH_fig<N>.json` / `BENCH_tables.json`
//! artifact (override the path with `DFEP_FIG_OUT`) alongside the
//! printed table, so CI can upload the figure trajectory the same way it
//! uploads the hotpath one. The `*_with(quick)` variants are the CI
//! smoke shape: fewer cells, one sample, same artifact schema.
//!
//! Scaling knobs (env):
//!   DFEP_SAMPLES  — seeded repetitions per point   (default 5; paper: 100)
//!   DFEP_SCALE    — dataset scale factor           (default 0.05; paper: 1.0)
//! `cargo bench` completes in minutes at the defaults; the paper-fidelity
//! run is `DFEP_SAMPLES=100 DFEP_SCALE=1.0 cargo bench`.

use crate::bench::harness::{fmt_f, sample_seeds, JsonSink, Table};
use crate::cluster::cost::CostModel;
use crate::cluster::dfep_mr::{resimulate, run_cluster_dfep};
use crate::cluster::etsch_mr::{run_baseline_sssp, run_etsch_sssp};
use crate::coordinator::batch::{BatchRequest, Variant};
use crate::etsch::gain::average_gain;
use crate::graph::{datasets, rewire, stats, Graph};
use crate::partition::spec::PartitionerSpec;
use crate::partition::view::PartitionView;
use crate::partition::{metrics, Partitioner};
use crate::util::stats::{mean, Summary};

/// Parse a bench-internal spec string (all of them are valid by
/// construction; a typo is a bench bug, so panic loudly).
pub fn spec(s: &str) -> PartitionerSpec {
    PartitionerSpec::parse(s)
        .unwrap_or_else(|e| panic!("bad bench spec '{s}': {e}"))
}

/// Seeded repetitions per data point (`DFEP_SAMPLES`, default 5).
pub fn samples() -> usize {
    std::env::var("DFEP_SAMPLES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(5)
}

/// Dataset scale factor (`DFEP_SCALE`, default 0.05; paper 1.0).
pub fn scale() -> f64 {
    std::env::var("DFEP_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.05)
}

/// Cluster figures need enough per-round volume for the overhead/work
/// ratio to be meaningful; they default to a larger scale than the
/// simulation figures (DFEP_CLUSTER_SCALE overrides).
pub fn cluster_scale() -> f64 {
    std::env::var("DFEP_CLUSTER_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| scale().max(0.25))
}

fn load(name: &str, scale_f: f64) -> Graph {
    let d = datasets::by_name(name).expect("dataset");
    if scale_f >= 1.0 {
        d.generate(42)
    } else {
        d.scaled(scale_f, 42)
    }
}

/// Write a figure artifact: `default_name` in the working directory, or
/// wherever `DFEP_FIG_OUT` points.
fn write_artifact(sink: &JsonSink, default_name: &str) {
    let out = std::env::var("DFEP_FIG_OUT")
        .unwrap_or_else(|_| default_name.to_string());
    let out_path = std::path::Path::new(&out);
    match sink.write(out_path) {
        Ok(()) => println!("\nwrote {}", out_path.display()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", out_path.display()),
    }
}

/// Record one measured cell under `prefix` (`<prefix>_nstdev`, ...).
fn sink_cell(sink: &mut JsonSink, prefix: &str, c: &Cell) {
    sink.num(&format!("{prefix}_largest"), c.largest.mean);
    sink.num(&format!("{prefix}_nstdev"), c.nstdev.mean);
    sink.num(&format!("{prefix}_messages"), c.messages.mean);
    sink.num(&format!("{prefix}_rounds"), c.rounds.mean);
    if c.gain.n > 0 {
        sink.num(&format!("{prefix}_gain"), c.gain.mean);
    }
}

/// Averaged metrics for one (partitioner, graph, k) cell.
pub struct Cell {
    /// Largest normalized part size across samples.
    pub largest: Summary,
    /// NSTDEV (§V-A) across samples.
    pub nstdev: Summary,
    /// MESSAGES (frontier replica count) across samples.
    pub messages: Summary,
    /// Partitioner rounds across samples.
    pub rounds: Summary,
    /// Path-compression gain across samples (empty if not measured).
    pub gain: Summary,
    /// Disconnected-partition fraction across samples.
    pub disconnected: Summary,
}

/// Run one (spec, graph, k) cell: the `samples` seeded repetitions
/// become the variants of one batch ([`BatchRequest::execute_on`]), so
/// they fan out over the ambient pool lanes with recycled DFEP scratch.
/// The per-seed reports are bit-identical to the sequential
/// [`PartitionRequest::execute_on`](crate::coordinator::runs::PartitionRequest::execute_on)
/// loop this replaced (that equivalence is pinned for every registry
/// spec in `tests/batch.rs`).
pub fn measure(
    g: &Graph,
    spec: &PartitionerSpec,
    k: usize,
    samples: usize,
    gain_samples: usize,
) -> Cell {
    let seeds = sample_seeds(samples, 0xF16);
    let breq = BatchRequest {
        dataset: String::new(),
        graph_seed: 42,
        variants: seeds
            .iter()
            .map(|&s| Variant { spec: spec.clone(), k, seed: s })
            .collect(),
        gain_samples,
        workload: None,
        threads: None,
    };
    let rep = breq
        .execute_on(g)
        .unwrap_or_else(|e| panic!("bench run '{spec}' failed: {e}"));
    let mut largest = Vec::new();
    let mut nstdev = Vec::new();
    let mut messages = Vec::new();
    let mut rounds = Vec::new();
    let mut gains = Vec::new();
    let mut disc = Vec::new();
    for res in &rep.reports {
        let r = &res.metrics;
        largest.push(r.largest);
        nstdev.push(r.nstdev);
        messages.push(r.messages as f64);
        rounds.push(r.rounds as f64);
        disc.push(r.disconnected);
        if let Some(gain) = res.gain {
            gains.push(gain);
        }
    }
    Cell {
        largest: Summary::of(&largest),
        nstdev: Summary::of(&nstdev),
        messages: Summary::of(&messages),
        rounds: Summary::of(&rounds),
        gain: Summary::of(&gains),
        disconnected: Summary::of(&disc),
    }
}

/// Fig 5: DFEP & DFEPC vs K on ASTROPH and USROADS.
pub fn fig5() {
    fig5_with(false);
}

/// Fig 5 runner; `quick` is the CI smoke shape (one dataset, three K
/// values, one sample — same artifact schema).
pub fn fig5_with(quick: bool) {
    let n = if quick { 1 } else { samples() };
    let sc = scale();
    let mut sink = JsonSink::new();
    sink.text("bench", "fig5");
    sink.num("quick", if quick { 1.0 } else { 0.0 });
    sink.num("samples", n as f64);
    sink.num("scale", sc);
    let datasets: &[&str] =
        if quick { &["astroph"] } else { &["astroph", "usroads"] };
    let ks: &[usize] =
        if quick { &[2, 8, 32] } else { &[2, 4, 8, 16, 32, 64, 128] };
    println!("Fig 5 — DFEP/DFEPC vs K  (samples={n}, scale={sc})");
    for &ds in datasets {
        let g = load(ds, sc);
        println!(
            "\n[{ds}] |V|={} |E|={}",
            g.vertex_count(),
            g.edge_count()
        );
        sink.num(&format!("{ds}_vertices"), g.vertex_count() as f64);
        sink.num(&format!("{ds}_edges"), g.edge_count() as f64);
        let mut t = Table::new(&[
            "algo", "K", "largest", "nstdev", "messages", "rounds", "gain",
        ]);
        for &k in ks {
            for (name, p) in
                [("DFEP", spec("dfep")), ("DFEPC", spec("dfepc"))]
            {
                let c = measure(&g, &p, k, n, 2);
                t.row(&[
                    name.into(),
                    k.to_string(),
                    fmt_f(c.largest.mean),
                    fmt_f(c.nstdev.mean),
                    fmt_f(c.messages.mean),
                    fmt_f(c.rounds.mean),
                    fmt_f(c.gain.mean),
                ]);
                sink_cell(
                    &mut sink,
                    &format!("{ds}_{}_k{k}", name.to_lowercase()),
                    &c,
                );
            }
        }
    }
    println!(
        "\nshape check (paper): nstdev & messages rise with K; rounds and \
         gain fall with K."
    );
    write_artifact(&sink, "BENCH_fig5.json");
}

/// Fig 6: DFEP vs diameter (rewired USROADS), K = 20.
pub fn fig6() {
    fig6_with(false);
}

/// Fig 6 runner; `quick` trims the rewire fractions to three and runs
/// one sample per point.
pub fn fig6_with(quick: bool) {
    let n = if quick { 1 } else { samples() };
    let sc = scale();
    let g0 = load("usroads", sc);
    let mut sink = JsonSink::new();
    sink.text("bench", "fig6");
    sink.num("quick", if quick { 1.0 } else { 0.0 });
    sink.num("samples", n as f64);
    sink.num("scale", sc);
    sink.num("vertices", g0.vertex_count() as f64);
    sink.num("edges", g0.edge_count() as f64);
    println!(
        "Fig 6 — DFEP vs diameter (rewired USROADS, K=20, samples={n}, \
         scale={sc})"
    );
    println!("|V|={} |E|={}", g0.vertex_count(), g0.edge_count());
    let mut t = Table::new(&[
        "remap%", "diam", "largest", "nstdev", "messages", "rounds",
        "gain", "disc%",
    ]);
    let fracs: &[f64] = if quick {
        &[0.0, 0.1, 0.4]
    } else {
        &[0.0, 0.01, 0.02, 0.05, 0.1, 0.2, 0.4]
    };
    for &frac in fracs {
        let g = rewire::rewire_fraction(&g0, frac, 7);
        let d = stats::diameter_estimate(&g, 4, 1);
        let c = measure(&g, &spec("dfep"), 20, n, 2);
        t.row(&[
            fmt_f(frac * 100.0),
            d.to_string(),
            fmt_f(c.largest.mean),
            fmt_f(c.nstdev.mean),
            fmt_f(c.messages.mean),
            fmt_f(c.rounds.mean),
            fmt_f(c.gain.mean),
            fmt_f(c.disconnected.mean * 100.0),
        ]);
        // key by permille so 1% and 10% stay distinct
        let prefix = format!("remap{}", (frac * 1000.0).round() as u64);
        sink.num(&format!("{prefix}_diameter"), d as f64);
        sink_cell(&mut sink, &prefix, &c);
        sink.num(
            &format!("{prefix}_disconnected_pct"),
            c.disconnected.mean * 100.0,
        );
    }
    println!(
        "\nshape check (paper): largest/nstdev/rounds/gain rise with \
         diameter; messages fall."
    );
    write_artifact(&sink, "BENCH_fig6.json");
}

/// Fig 7: DFEP vs DFEPC vs JaBeJa on the four simulation datasets, K=20.
pub fn fig7() {
    fig7_with(false);
}

/// Fig 7 runner; `quick` keeps one small-world and one road dataset.
pub fn fig7_with(quick: bool) {
    let n = if quick { 1 } else { samples() };
    let sc = scale();
    let mut sink = JsonSink::new();
    sink.text("bench", "fig7");
    sink.num("quick", if quick { 1.0 } else { 0.0 });
    sink.num("samples", n as f64);
    sink.num("scale", sc);
    let datasets: &[&str] = if quick {
        &["astroph", "usroads"]
    } else {
        &["astroph", "email-enron", "usroads", "wordnet"]
    };
    println!("Fig 7 — DFEP/DFEPC/JaBeJa, K=20 (samples={n}, scale={sc})");
    for &ds in datasets {
        let g = load(ds, sc);
        println!(
            "\n[{ds}] |V|={} |E|={}",
            g.vertex_count(),
            g.edge_count()
        );
        let mut t = Table::new(&[
            "algo", "largest", "nstdev", "messages", "rounds", "gain",
        ]);
        for (name, p) in [
            ("DFEP", spec("dfep")),
            ("DFEPC", spec("dfepc")),
            ("JaBeJa", spec("jabeja")),
        ] {
            let c = measure(&g, &p, 20, n, 2);
            t.row(&[
                name.into(),
                fmt_f(c.largest.mean),
                fmt_f(c.nstdev.mean),
                fmt_f(c.messages.mean),
                fmt_f(c.rounds.mean),
                fmt_f(c.gain.mean),
            ]);
            sink_cell(
                &mut sink,
                &format!("{ds}_{}", name.to_lowercase()),
                &c,
            );
        }
    }
    println!(
        "\nshape check (paper): small-world -> DFEP/DFEPC more balanced at \
         similar gain; USROADS -> JaBeJa more balanced but ~10x messages \
         and lower gain."
    );
    write_artifact(&sink, "BENCH_fig7.json");
}

/// Fig 8: DFEP speedup on the simulated EC2 cluster, K=20, nodes 2..16.
pub fn fig8() {
    fig8_with(false);
}

/// Fig 8 runner; `quick` keeps one dataset at the (smaller) simulation
/// scale. The cluster simulation is round-structured, not per-seed, so
/// this figure stays on the MapReduce simulator rather than the batch
/// engine.
pub fn fig8_with(quick: bool) {
    let sc = if quick { scale() } else { cluster_scale() };
    let cost = CostModel::default();
    let mut sink = JsonSink::new();
    sink.text("bench", "fig8");
    sink.num("quick", if quick { 1.0 } else { 0.0 });
    sink.num("scale", sc);
    println!("Fig 8 — DFEP cluster speedup, K=20 (scale={sc})");
    let mut t = Table::new(&[
        "dataset", "nodes", "time_s", "speedup_vs_2",
    ]);
    let datasets: &[&str] =
        if quick { &["dblp"] } else { &["dblp", "youtube", "amazon"] };
    for &ds in datasets {
        let g = load(ds, sc);
        let run = run_cluster_dfep(&g, 20, 2, 7, &cost, 2000);
        let t2 = run.total_time;
        for nodes in [2usize, 4, 8, 16] {
            let tt = resimulate(&run, nodes, &cost);
            t.row(&[
                ds.into(),
                nodes.to_string(),
                fmt_f(tt),
                fmt_f(t2 / tt),
            ]);
            sink.num(&format!("{ds}_n{nodes}_time_s"), tt);
            sink.num(&format!("{ds}_n{nodes}_speedup_vs_2"), t2 / tt);
        }
    }
    println!(
        "\nshape check (paper): speedup > 5x at 16 nodes vs 2 on the \
         larger datasets."
    );
    write_artifact(&sink, "BENCH_fig8.json");
}

/// Fig 9: ETSCH SSSP vs vertex-centric baseline on the cluster.
pub fn fig9() {
    fig9_with(false);
}

/// Fig 9 runner; `quick` keeps one dataset at the simulation scale.
pub fn fig9_with(quick: bool) {
    let sc = if quick { scale() } else { cluster_scale() };
    let cost = CostModel::default();
    let mut sink = JsonSink::new();
    sink.text("bench", "fig9");
    sink.num("quick", if quick { 1.0 } else { 0.0 });
    sink.num("scale", sc);
    println!("Fig 9 — SSSP: ETSCH vs vertex-centric baseline (scale={sc})");
    let mut t = Table::new(&[
        "dataset", "nodes", "etsch_s", "rounds", "baseline_s",
        "supersteps", "ratio",
    ]);
    let datasets: &[&str] =
        if quick { &["dblp"] } else { &["dblp", "youtube", "amazon"] };
    for &ds in datasets {
        let g = load(ds, sc);
        for nodes in [2usize, 4, 8, 16] {
            let p = spec("dfep")
                .build()
                .partition_graph(&g, nodes, 7)
                .expect("bench partition");
            let e = run_etsch_sssp(&g, &p, 0, nodes, &cost);
            let b = run_baseline_sssp(&g, 0, nodes, &cost);
            assert_eq!(e.distances, b.distances, "correctness");
            t.row(&[
                ds.into(),
                nodes.to_string(),
                fmt_f(e.total_time),
                e.rounds.to_string(),
                fmt_f(b.total_time),
                b.rounds.to_string(),
                fmt_f(b.total_time / e.total_time),
            ]);
            sink.num(&format!("{ds}_n{nodes}_etsch_s"), e.total_time);
            sink.num(&format!("{ds}_n{nodes}_baseline_s"), b.total_time);
            sink.num(
                &format!("{ds}_n{nodes}_ratio"),
                b.total_time / e.total_time,
            );
        }
    }
    println!(
        "\nshape check (paper): ETSCH faster everywhere; advantage \
         largest at few nodes and narrows as nodes grow."
    );
    write_artifact(&sink, "BENCH_fig9.json");
}

/// Tables II & III: paper-reported vs generated dataset statistics.
pub fn tables() {
    tables_with(false);
}

/// Tables runner; `quick` keeps the four simulation datasets only.
pub fn tables_with(quick: bool) {
    let sc = scale();
    let mut sink = JsonSink::new();
    sink.text("bench", "tables");
    sink.num("quick", if quick { 1.0 } else { 0.0 });
    sink.num("scale", sc);
    println!("Tables II/III — dataset calibration (scale={sc})");
    let mut t = Table::new(&[
        "dataset", "V_paper", "V_gen", "E_paper", "E_gen", "D_paper",
        "D_gen", "CC_paper", "CC_gen", "RCC_gen",
    ]);
    let ds: Vec<_> = if quick {
        datasets::simulation_datasets()
    } else {
        datasets::simulation_datasets()
            .into_iter()
            .chain(datasets::ec2_datasets())
            .collect()
    };
    for d in ds {
        let g = if sc >= 1.0 { d.generate(42) } else { d.scaled(sc, 42) };
        let s = stats::graph_stats(&g, 1);
        t.row(&[
            d.name.into(),
            d.paper.v.to_string(),
            s.vertices.to_string(),
            d.paper.e.to_string(),
            s.edges.to_string(),
            d.paper.d.to_string(),
            s.diameter.to_string(),
            format!("{:.2e}", d.paper.cc),
            format!("{:.2e}", s.clustering),
            format!("{:.2e}", s.random_cc),
        ]);
        sink.num(&format!("{}_vertices", d.name), s.vertices as f64);
        sink.num(&format!("{}_edges", d.name), s.edges as f64);
        sink.num(&format!("{}_diameter", d.name), s.diameter as f64);
        sink.num(&format!("{}_clustering", d.name), s.clustering);
        sink.num(&format!("{}_random_cc", d.name), s.random_cc);
    }
    if sc < 1.0 {
        println!(
            "(scaled instances: V/E shrink with the factor; run with \
             DFEP_SCALE=1.0 for the full-size calibration check)"
        );
    }
    write_artifact(&sink, "BENCH_tables.json");
}

/// Ablations + hot-path micro benches (feeds EXPERIMENTS.md §Perf).
pub fn hotpath() {
    hotpath_with(false);
}

/// Hot-path bench. `quick` is the CI smoke mode: a small graph and a
/// single repetition, just enough for the JSON artifact to accumulate a
/// perf trajectory on every push.
pub fn hotpath_with(quick: bool) {
    let n = if quick { 1 } else { samples().max(3) };
    let mut sink = crate::bench::harness::JsonSink::new();
    sink.text("bench", "hotpath");
    sink.num("quick", if quick { 1.0 } else { 0.0 });

    // ---- pool thread-scaling on the DFEP round loop ----
    // acceptance target: >= 2x speedup with 8 pool threads vs 1 on a
    // >= 100k-edge power-law graph, with bit-identical partitions and
    // round counts across thread counts
    {
        use crate::graph::generators::GraphKind;
        use crate::util::pool;
        let scale_kind = if quick {
            GraphKind::PowerlawCluster { n: 2_000, m: 6, p: 0.3 }
        } else {
            GraphKind::PowerlawCluster { n: 20_000, m: 6, p: 0.3 }
        };
        let gs = scale_kind.generate(42);
        println!(
            "pool scaling graph: |V|={} |E|={}",
            gs.vertex_count(),
            gs.edge_count()
        );
        sink.num("scaling_vertices", gs.vertex_count() as f64);
        sink.num("scaling_edges", gs.edge_count() as f64);
        let mut t = Table::new(&["threads", "mean_s", "Medges/s", "speedup"]);
        let mut base_owner: Vec<u32> = Vec::new();
        let mut base_rounds = 0usize;
        let mut base_mean = 0.0f64;
        let mut identical = true;
        let dfep = spec("dfep").build();
        for threads in [1usize, 2, 4, 8] {
            let (part, times) = pool::with_threads(threads, || {
                let part =
                    dfep.partition_graph(&gs, 8, 1).expect("bench dfep");
                let times = crate::util::timer::time_n(
                    if quick { 0 } else { 1 },
                    n,
                    || {
                        let _ = dfep.partition_graph(&gs, 8, 1);
                    },
                );
                (part, times)
            });
            let s = Summary::of(&times);
            if threads == 1 {
                base_owner = part.owner.clone();
                base_rounds = part.rounds;
                base_mean = s.mean;
            } else if part.owner != base_owner || part.rounds != base_rounds
            {
                identical = false;
            }
            t.row(&[
                threads.to_string(),
                fmt_f(s.mean),
                fmt_f(gs.edge_count() as f64 / s.mean / 1e6),
                fmt_f(base_mean / s.mean),
            ]);
            sink.num(&format!("dfep_k8_{threads}t_mean_s"), s.mean);
            if threads == 8 {
                sink.num("dfep_k8_speedup_8t", base_mean / s.mean);
            }
        }
        println!(
            "partitions bit-identical across 1/2/4/8 threads: {identical}"
        );
        sink.num("identical_across_threads", if identical { 1.0 } else { 0.0 });
        assert!(
            identical,
            "thread count changed the partition trajectory"
        );
    }

    println!("\nhot paths (samples={n})");
    let g = if quick {
        datasets::astroph().scaled(0.05, 42)
    } else {
        datasets::astroph().scaled(0.25, 42)
    };
    println!("graph: |V|={} |E|={}", g.vertex_count(), g.edge_count());
    sink.num("hotpath_vertices", g.vertex_count() as f64);
    sink.num("hotpath_edges", g.edge_count() as f64);

    // DFEP partition throughput
    let warmup = if quick { 0 } else { 1 };
    let mut t = Table::new(&["path", "mean_s", "p95_s", "Medges/s"]);
    for (name, key, s) in [
        ("DFEP k=8", "dfep_default_mean_s", "dfep"),
        (
            "DFEP k=8 literal-Alg4 (ablation)",
            "dfep_literal_alg4_mean_s",
            "dfep:frontier_first=false,max_rounds=300",
        ),
    ] {
        let p = spec(s).build();
        let times = crate::util::timer::time_n(warmup, n, || {
            let _ = p.partition_graph(&g, 8, 1);
        });
        let s = Summary::of(&times);
        t.row(&[
            name.into(),
            fmt_f(s.mean),
            fmt_f(s.p95),
            fmt_f(g.edge_count() as f64 / s.mean / 1e6),
        ]);
        sink.num(key, s.mean);
    }

    // ETSCH round loop
    let p = spec("dfep")
        .build()
        .partition_graph(&g, 8, 1)
        .expect("bench dfep");
    let times = crate::util::timer::time_n(warmup, n, || {
        let mut engine = crate::etsch::Etsch::new(&g, &p);
        let _ = engine.run(&mut crate::etsch::sssp::Sssp::new(0));
    });
    let s = Summary::of(&times);
    t.row(&[
        "ETSCH sssp (build+run)".into(),
        fmt_f(s.mean),
        fmt_f(s.p95),
        fmt_f(g.edge_count() as f64 / s.mean / 1e6),
    ]);
    sink.num("etsch_sssp_mean_s", s.mean);

    // dfep_round series: the round engine itself — drives DfepState
    // directly (no finalize, no trace), reporting rounds/sec,
    // edges-bought/sec and the high-water footprint of the persistent
    // RoundScratch (which makes steady-state rounds allocation-free;
    // see tests/alloc_budget.rs)
    {
        use crate::partition::dfep::{reseed_on_free_edge, DfepState};
        use crate::util::rng::Rng;
        let kk = 8usize;
        let initial = (g.edge_count() as f64 / kk as f64).max(1.0);
        let mut rounds = 0usize;
        let mut bought = 0usize;
        let mut peak = 0usize;
        let times = crate::util::timer::time_n(warmup, n, || {
            let mut rng = Rng::new(1);
            let mut st = DfepState::new(&g, kk, initial, &mut rng);
            let mut stall = 0usize;
            while st.free_edges > 0 && st.rounds < 4_000 {
                let before = st.free_edges;
                st.funding_round(&g, None, None);
                st.coordinator_step(10.0);
                if st.free_edges == before {
                    stall += 1;
                    if stall >= 3 {
                        reseed_on_free_edge(&g, &mut st, &mut rng);
                        stall = 0;
                    }
                } else {
                    stall = 0;
                }
            }
            rounds = st.rounds;
            bought = st.sizes.iter().sum();
            peak = st.scratch_peak_bytes();
        });
        let s = Summary::of(&times);
        t.row(&[
            format!("DFEP round engine ({rounds} rounds)"),
            fmt_f(s.mean),
            fmt_f(s.p95),
            fmt_f(bought as f64 / s.mean / 1e6),
        ]);
        println!(
            "dfep_round: {} rounds/s, {} edges-bought/s, scratch peak {} \
             bytes",
            fmt_f(rounds as f64 / s.mean),
            fmt_f(bought as f64 / s.mean),
            peak
        );
        sink.num("dfep_round_mean_s", s.mean);
        sink.num("dfep_round_rounds_per_s", rounds as f64 / s.mean);
        sink.num(
            "dfep_round_edges_bought_per_s",
            bought as f64 / s.mean,
        );
        sink.num("dfep_round_scratch_peak_bytes", peak as f64);
    }

    // partition_view series: the shared derived-state layer — one view
    // build, the full metric evaluation on top of it, and engine
    // construction (which is exactly one view build since PR 2)
    let view = PartitionView::build(&g, &p);
    {
        let mut series = |name: &str, key: &str, times: Vec<f64>| {
            let s = Summary::of(&times);
            t.row(&[
                name.into(),
                fmt_f(s.mean),
                fmt_f(s.p95),
                fmt_f(g.edge_count() as f64 / s.mean / 1e6),
            ]);
            sink.num(key, s.mean);
        };
        series(
            "PartitionView build",
            "partition_view_build_mean_s",
            crate::util::timer::time_n(warmup, n, || {
                let _ = PartitionView::build(&g, &p);
            }),
        );
        series(
            "metrics::evaluate_with (prebuilt view)",
            "metrics_evaluate_mean_s",
            crate::util::timer::time_n(warmup, n, || {
                let _ = metrics::evaluate_with(&g, &p, &view);
            }),
        );
        series(
            "Etsch::new (view build)",
            "etsch_new_mean_s",
            crate::util::timer::time_n(warmup, n, || {
                let _ = crate::etsch::Etsch::new(&g, &p);
            }),
        );
    }

    // streaming series: ingest-time partitioner throughput (edges/sec),
    // with the materializing StreamingGreedy as the comparison point
    {
        let m = g.edge_count() as f64;
        let mut series = |name: &str, key: &str, times: Vec<f64>| {
            let s = Summary::of(&times);
            t.row(&[
                name.into(),
                fmt_f(s.mean),
                fmt_f(s.p95),
                fmt_f(m / s.mean / 1e6),
            ]);
            sink.num(key, m / s.mean.max(1e-12));
        };
        series(
            "HDRF (stream ingest)",
            "streaming_hdrf_edges_per_s",
            crate::util::timer::time_n(warmup, n, || {
                let _ = spec("hdrf").build().partition_graph(&g, 8, 1);
            }),
        );
        series(
            "DBH (stream ingest, 2 passes)",
            "streaming_dbh_edges_per_s",
            crate::util::timer::time_n(warmup, n, || {
                let _ = spec("dbh").build().partition_graph(&g, 8, 1);
            }),
        );
        series(
            "ReStream (HDRF + 1 refine)",
            "streaming_restream_edges_per_s",
            crate::util::timer::time_n(warmup, n, || {
                let _ = spec("restream").build().partition_graph(&g, 8, 1);
            }),
        );
        series(
            "StreamingGreedy (materialized)",
            "streaming_greedy_edges_per_s",
            crate::util::timer::time_n(warmup, n, || {
                let _ = spec("fennel").build().partition_graph(&g, 8, 1);
            }),
        );
    }

    // refine series: the local-search post-pass (partition::refine) —
    // an HDRF base built once outside the loop, then RefineEngine
    // construction + a 4-round run per sample, reporting accepted
    // changes/sec and the replication-factor delta the pass buys
    // (tests/refine.rs pins delta >= 0, tests/refine_alloc.rs pins the
    // steady-state allocation budget)
    {
        use crate::partition::refine::RefineEngine;
        let base = spec("hdrf")
            .build()
            .partition_graph(&g, 8, 1)
            .expect("bench hdrf base");
        let nverts = g.vertex_count() as f64;
        let rf_before =
            PartitionView::build(&g, &base).replica_total() as f64 / nverts;
        let mut moved = 0usize;
        let mut rf_after = rf_before;
        let times = crate::util::timer::time_n(warmup, n, || {
            let mut eng = RefineEngine::new(&g, &base, 0.05);
            moved = eng.run(&g, 4);
            rf_after = eng.total_replicas() as f64 / nverts;
        });
        let s = Summary::of(&times);
        t.row(&[
            format!("refine 4 rounds ({moved} changes)"),
            fmt_f(s.mean),
            fmt_f(s.p95),
            fmt_f(g.edge_count() as f64 / s.mean / 1e6),
        ]);
        println!(
            "refine: {} changes/s, RF {} -> {} (delta {})",
            fmt_f(moved as f64 / s.mean),
            fmt_f(rf_before),
            fmt_f(rf_after),
            fmt_f(rf_before - rf_after)
        );
        sink.num("refine_mean_s", s.mean);
        sink.num("refine_moves_per_s", moved as f64 / s.mean.max(1e-12));
        sink.num("refine_rf_delta", rf_before - rf_after);
    }

    // batch series: the multi-(seed,k) engine vs the sequential facade
    // loop it replaces. Acceptance target: >= 2x on an 8-variant sweep
    // at 8 pool threads, with (tests/batch.rs) bit-identical reports.
    {
        use crate::util::pool;
        let sweep: [(usize, u64); 8] = [
            (2, 1),
            (2, 2),
            (4, 1),
            (4, 2),
            (8, 1),
            (8, 2),
            (16, 1),
            (16, 2),
        ];
        let breq = BatchRequest {
            dataset: String::new(),
            graph_seed: 42,
            variants: sweep
                .iter()
                .map(|&(k, s)| Variant { spec: spec("dfep"), k, seed: s })
                .collect(),
            gain_samples: 0,
            workload: None,
            threads: None,
        };
        let nvars = breq.variants.len();
        let seq_times = crate::util::timer::time_n(warmup, n, || {
            for v in &breq.variants {
                let _ = breq
                    .request_for(v)
                    .execute_on(&g)
                    .expect("bench sequential variant");
            }
        });
        let seq = Summary::of(&seq_times);
        let (batch_rep, batch_times) = pool::with_threads(8, || {
            let rep = breq.execute_on(&g).expect("bench batch");
            let times = crate::util::timer::time_n(warmup, n, || {
                let _ = breq.execute_on(&g);
            });
            (rep, times)
        });
        let s = Summary::of(&batch_times);
        t.row(&[
            format!("batch {nvars} variants / 8 lanes"),
            fmt_f(s.mean),
            fmt_f(s.p95),
            fmt_f(nvars as f64 * g.edge_count() as f64 / s.mean / 1e6),
        ]);
        t.row(&[
            format!("batch {nvars} variants sequential"),
            fmt_f(seq.mean),
            fmt_f(seq.p95),
            fmt_f(nvars as f64 * g.edge_count() as f64 / seq.mean / 1e6),
        ]);
        println!(
            "batch: {} variants/s over {} lane(s), {}x vs sequential, \
             scratch peak {} bytes",
            fmt_f(nvars as f64 / s.mean),
            batch_rep.lanes,
            fmt_f(seq.mean / s.mean),
            batch_rep.scratch_peak_bytes
        );
        sink.num("batch_mean_s", s.mean);
        sink.num("batch_sequential_mean_s", seq.mean);
        sink.num("batch_variants_per_s", nvars as f64 / s.mean);
        sink.num("batch_speedup_vs_sequential", seq.mean / s.mean);
        sink.num("batch_lanes", batch_rep.lanes as f64);
        sink.num(
            "batch_scratch_peak_bytes",
            batch_rep.scratch_peak_bytes as f64,
        );
    }

    // XLA runtime paths (L1 kernel tile + L2 fused fixpoint + funding)
    if let Ok(rt) = crate::runtime::Runtime::open_default() {
        use crate::runtime::{Tensor, INF32};
        let exe = rt.load("minplus_block_256").unwrap();
        let a = vec![INF32; 256 * 256];
        let x = vec![INF32; 256];
        let times = crate::util::timer::time_n(2, n.max(10), || {
            let _ = exe
                .run(&[Tensor::F32(a.clone()), Tensor::F32(x.clone())])
                .unwrap();
        });
        let s = Summary::of(&times);
        t.row(&[
            "XLA minplus_block_256 (1 tile)".into(),
            fmt_f(s.mean),
            fmt_f(s.p95),
            fmt_f(256.0 * 256.0 / s.mean / 1e6),
        ]);
        let big = view
            .subgraphs()
            .iter()
            .max_by_key(|s| s.vertex_count())
            .unwrap();
        let tiled =
            crate::runtime::blocktiled::TiledSubgraph::pack(big, 1.0);
        let mut init = vec![INF32; big.vertex_count()];
        init[0] = 0.0;
        let times = crate::util::timer::time_n(1, n, || {
            let _ = crate::runtime::blocktiled::relax_to_fixpoint(
                &rt, &tiled, &init, 4096,
            )
            .unwrap();
        });
        let s = Summary::of(&times);
        t.row(&[
            format!(
                "XLA tiled local phase ({}v/{}tiles)",
                big.vertex_count(),
                tiled.tiles.len()
            ),
            fmt_f(s.mean),
            fmt_f(s.p95),
            fmt_f(big.edge_count as f64 / s.mean / 1e6),
        ]);
    } else {
        println!("(XLA rows skipped: artifacts not built)");
    }

    // gain vs baselines snapshot
    let dfep_gain = average_gain(&g, &p, 3, 1);
    println!("\ngain(DFEP k=8) = {}", fmt_f(dfep_gain));
    let lit = spec("dfep:frontier_first=false,max_rounds=300")
        .build()
        .partition_graph(&g, 8, 1)
        .expect("bench dfep ablation");
    println!(
        "ablation literal-Alg4: rounds {} (capped) nstdev {} vs \
         frontier-first rounds {} nstdev {}",
        lit.rounds,
        fmt_f(metrics::nstdev(&g, &lit)),
        p.rounds,
        fmt_f(metrics::nstdev(&g, &p)),
    );
    sink.num("dfep_gain_k8", dfep_gain);
    let _ = mean(&[]);

    // persist the JSON artifact so CI can upload the perf trajectory
    let out = std::env::var("DFEP_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_hotpath.json".to_string());
    let out_path = std::path::Path::new(&out);
    match sink.write(out_path) {
        Ok(()) => println!("\nwrote {}", out_path.display()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", out_path.display()),
    }
}
