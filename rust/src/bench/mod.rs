//! Bench harness substrate (no `criterion` in the vendored set): sample
//! aggregation over seeds and paper-style table printing shared by the
//! `rust/benches/*` targets.

pub mod cluster_load;
pub mod figures;
pub mod harness;
pub mod serve_load;

pub use harness::{
    fmt_f, fmt_summary, print_header, print_row, sample_seeds, JsonSink,
    Table,
};
