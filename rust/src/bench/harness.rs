//! Text-table output + seed sweeps for the figure regenerators.
//!
//! Every figure bench prints the same series the paper plots: one row per
//! x-value (K, diameter, node count, ...), averaged over `--samples`
//! seeded runs, in aligned columns digestible by eyeball or awk.

use crate::util::stats::Summary;

/// Deterministic seed list for an n-sample experiment.
pub fn sample_seeds(samples: usize, base: u64) -> Vec<u64> {
    (0..samples as u64).map(|i| base ^ (i * 0x9E37_79B9 + 1)).collect()
}

/// Column-aligned table writer.
pub struct Table {
    widths: Vec<usize>,
    header: Vec<String>,
    printed_header: bool,
}

impl Table {
    /// New table with the given column headers (printed on first row).
    pub fn new(columns: &[&str]) -> Table {
        Table {
            widths: columns.iter().map(|c| c.len().max(10)).collect(),
            header: columns.iter().map(|s| s.to_string()).collect(),
            printed_header: false,
        }
    }

    /// Print one aligned row (prints the header first if needed).
    pub fn row(&mut self, cells: &[String]) {
        if !self.printed_header {
            self.print_header_line();
            self.printed_header = true;
        }
        let line: Vec<String> = cells
            .iter()
            .zip(self.widths.iter())
            .map(|(c, &w)| format!("{c:>w$}"))
            .collect();
        println!("{}", line.join("  "));
    }

    fn print_header_line(&self) {
        let line: Vec<String> = self
            .header
            .iter()
            .zip(self.widths.iter())
            .map(|(c, &w)| format!("{c:>w$}"))
            .collect();
        let joined = line.join("  ");
        println!("{joined}");
        println!("{}", "-".repeat(joined.len()));
    }
}

/// Format a float with magnitude-appropriate precision (the one number
/// formatter every bench table uses).
pub fn fmt_f(x: f64) -> String {
    if x == 0.0 {
        "0".into()
    } else if x.abs() >= 1000.0 {
        format!("{x:.0}")
    } else if x.abs() >= 1.0 {
        format!("{x:.3}")
    } else {
        format!("{x:.4}")
    }
}

/// Format a [`Summary`] as `mean±stdev`.
pub fn fmt_summary(s: &Summary) -> String {
    format!("{}±{}", fmt_f(s.mean), fmt_f(s.stdev))
}

/// Print a figure banner.
pub fn print_header(fig: &str, what: &str) {
    println!();
    println!("=== {fig}: {what} ===");
}

/// Print one labeled value row.
pub fn print_row(label: &str, value: &str) {
    println!("{label:<28} {value}");
}

/// Minimal flat-JSON artifact writer for bench outputs (CI uploads these
/// so the perf trajectory accumulates run over run). Keys keep insertion
/// order; values are numbers or strings.
#[derive(Default)]
pub struct JsonSink {
    entries: Vec<(String, String)>,
}

impl JsonSink {
    /// New empty sink.
    pub fn new() -> JsonSink {
        JsonSink::default()
    }

    /// Record a numeric field (non-finite values become null).
    pub fn num(&mut self, key: &str, value: f64) {
        let v = if value.is_finite() {
            format!("{value}")
        } else {
            "null".to_string()
        };
        self.entries.push((key.to_string(), v));
    }

    /// Record a string field (callers pass identifier-like values; quotes
    /// and backslashes are escaped).
    pub fn text(&mut self, key: &str, value: &str) {
        let escaped = value.replace('\\', "\\\\").replace('"', "\\\"");
        self.entries.push((key.to_string(), format!("\"{escaped}\"")));
    }

    /// Record a pre-rendered JSON value (the caller guarantees `json` is
    /// valid JSON — used for the one non-flat field in the crate, the
    /// serving layer's `"owners"` array).
    pub fn raw(&mut self, key: &str, json: String) {
        self.entries.push((key.to_string(), json));
    }

    /// Serialize as a single JSON object.
    pub fn render(&self) -> String {
        let body: Vec<String> = self
            .entries
            .iter()
            .map(|(k, v)| format!("  \"{k}\": {v}"))
            .collect();
        format!("{{\n{}\n}}\n", body.join(",\n"))
    }

    /// Write to `path`.
    pub fn write(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeds_distinct_and_deterministic() {
        let a = sample_seeds(10, 5);
        let b = sample_seeds(10, 5);
        assert_eq!(a, b);
        let set: std::collections::HashSet<_> = a.iter().collect();
        assert_eq!(set.len(), 10);
    }

    #[test]
    fn fmt_bands() {
        assert_eq!(fmt_f(0.0), "0");
        assert_eq!(fmt_f(0.1234567), "0.1235");
        assert_eq!(fmt_f(12.3), "12.300");
        assert_eq!(fmt_f(4321.9), "4322");
    }

    #[test]
    fn json_sink_renders_parseable_object() {
        let mut s = JsonSink::new();
        s.text("bench", "hotpath");
        s.num("edges", 123456.0);
        s.num("speedup", 2.5);
        s.num("bad", f64::NAN);
        let doc = s.render();
        let parsed = crate::util::json::parse(&doc).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str().unwrap(), "hotpath");
        assert_eq!(parsed.get("edges").unwrap().as_usize().unwrap(), 123456);
        assert!((parsed.get("speedup").unwrap().as_f64().unwrap() - 2.5).abs() < 1e-12);
        assert_eq!(*parsed.get("bad").unwrap(), crate::util::json::Json::Null);
    }
}
