//! `serve_load`: closed-loop load generator for the serving layer.
//!
//! Spawns an in-process [`Server`] on an ephemeral loopback port and
//! drives it with N closed-loop [`ServeClient`] threads (each waits for
//! its response before sending the next request — offered load tracks
//! service capacity, never overruns it). The key mix is ~90% *hot* (a
//! small set of pre-warmed cache keys, measuring the serving + cache
//! path) and ~10% *cold* (fresh partition seeds, measuring end-to-end
//! computation under concurrent load).
//!
//! Emits req/s and p50/p99 latency — overall and split by hot/cold —
//! plus the server's own cache counters into `BENCH_serve.json`
//! (override with `DFEP_SERVE_OUT`), mirroring the hotpath artifact that
//! CI uploads and diffs run over run.

use std::time::Instant;

use crate::bench::harness::JsonSink;
use crate::bench::{fmt_f, Table};
use crate::coordinator::runs::PartitionRequest;
use crate::coordinator::serve::{ServeClient, ServeConfig, Server};
use crate::util::rng::Rng;

/// Number of distinct pre-warmed hot cache keys.
const HOT_KEYS: u64 = 4;

fn request(seed: u64) -> PartitionRequest {
    PartitionRequest::new("dfep")
        .expect("dfep is registered")
        .dataset("er:n=2000,m=6000")
        .k(8)
        .seed(seed)
}

/// Nearest-rank percentile of an ascending-sorted sample.
fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Run the load generator; `quick` is the CI smoke shape.
pub fn serve_load_with(quick: bool) {
    let (clients, per_client) = if quick { (4usize, 25usize) } else { (8usize, 150usize) };
    let handle = Server::spawn(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: clients,
        ..Default::default()
    })
    .expect("bind loopback server");
    let addr = handle.addr();
    println!(
        "serve_load: {clients} closed-loop clients x {per_client} requests \
         against {addr} ({HOT_KEYS} hot keys, ~10% cold)"
    );

    // warm the hot keys so the steady-state mix measures cache serving,
    // not four initial cold misses
    let mut warm = ServeClient::connect(addr);
    for s in 1..=HOT_KEYS {
        warm.partition(&request(s), false).expect("warmup request");
    }

    let t0 = Instant::now();
    let per_thread: Vec<(Vec<f64>, Vec<f64>, usize)> =
        std::thread::scope(|scope| {
            let threads: Vec<_> = (0..clients)
                .map(|c| {
                    scope.spawn(move || {
                        let mut rng = Rng::new(0xC0FF_EE00 ^ c as u64);
                        let mut client = ServeClient::connect(addr);
                        let mut hot = Vec::new();
                        let mut cold = Vec::new();
                        let mut errors = 0usize;
                        for i in 0..per_client {
                            let is_hot = rng.next_u32() % 10 != 0;
                            let seed = if is_hot {
                                1 + rng.next_u32() as u64 % HOT_KEYS
                            } else {
                                // unique per (client, iteration): always
                                // a fresh computation
                                10_000 + (c * 100_000 + i) as u64
                            };
                            let t = Instant::now();
                            match client.partition(&request(seed), false) {
                                Ok(_) => {
                                    let secs = t.elapsed().as_secs_f64();
                                    if is_hot {
                                        hot.push(secs);
                                    } else {
                                        cold.push(secs);
                                    }
                                }
                                Err(_) => errors += 1,
                            }
                        }
                        (hot, cold, errors)
                    })
                })
                .collect();
            threads.into_iter().map(|t| t.join().unwrap()).collect()
        });
    let wall = t0.elapsed().as_secs_f64();

    let mut hot = Vec::new();
    let mut cold = Vec::new();
    let mut errors = 0usize;
    for (h, c, e) in per_thread {
        hot.extend(h);
        cold.extend(c);
        errors += e;
    }
    let mut all: Vec<f64> = hot.iter().chain(cold.iter()).copied().collect();
    all.sort_by(f64::total_cmp);
    hot.sort_by(f64::total_cmp);
    cold.sort_by(f64::total_cmp);
    assert_eq!(errors, 0, "load generator saw request errors");
    let total = all.len();
    let rps = total as f64 / wall.max(1e-9);

    let ms = |s: f64| s * 1e3;
    let mut t = Table::new(&["mix", "n", "p50_ms", "p99_ms", "max_ms"]);
    for (name, v) in [("all", &all), ("hot", &hot), ("cold", &cold)] {
        t.row(&[
            name.to_string(),
            v.len().to_string(),
            fmt_f(ms(percentile(v, 0.50))),
            fmt_f(ms(percentile(v, 0.99))),
            fmt_f(ms(v.last().copied().unwrap_or(0.0))),
        ]);
    }
    println!(
        "\n{total} requests in {} s -> {} req/s",
        fmt_f(wall),
        fmt_f(rps)
    );

    // the server's own accounting, straight off /stats
    let mut probe = ServeClient::connect(addr);
    let (status, stats_body) = probe.get("/stats").expect("stats probe");
    assert_eq!(status, 200);
    let stats = crate::util::json::parse(&stats_body).expect("stats JSON");
    let stat = |key: &str| {
        stats.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0)
    };
    println!(
        "server: {} computations, cache hit rate {}",
        stat("computations"),
        fmt_f(stat("cache_hit_rate"))
    );
    // cold-path attribution: how much of a cold p99 is dataset
    // resolution (graph build) rather than partitioning. With one
    // dataset in the mix this is one resolve, amortized across every
    // cold request.
    println!(
        "server: {} graph resolve(s), mean {} ms, max {} ms",
        stat("resolve_count"),
        fmt_f(stat("resolve_mean_ms")),
        fmt_f(stat("resolve_max_ms"))
    );

    let mut sink = JsonSink::new();
    sink.text("bench", "serve_load");
    sink.num("quick", if quick { 1.0 } else { 0.0 });
    sink.num("clients", clients as f64);
    sink.num("requests_total", total as f64);
    sink.num("errors", errors as f64);
    sink.num("wall_s", wall);
    sink.num("req_per_s", rps);
    sink.num("p50_ms", ms(percentile(&all, 0.50)));
    sink.num("p99_ms", ms(percentile(&all, 0.99)));
    sink.num("hot_p50_ms", ms(percentile(&hot, 0.50)));
    sink.num("hot_p99_ms", ms(percentile(&hot, 0.99)));
    sink.num("cold_p50_ms", ms(percentile(&cold, 0.50)));
    sink.num("cold_p99_ms", ms(percentile(&cold, 0.99)));
    sink.num("cache_hit_rate", stat("cache_hit_rate"));
    sink.num("computations", stat("computations"));
    sink.num("resolve_count", stat("resolve_count"));
    sink.num("resolve_mean_ms", stat("resolve_mean_ms"));
    sink.num("resolve_max_ms", stat("resolve_max_ms"));
    sink.num(
        "shed_total",
        stat("shed_queue_full")
            + stat("shed_busy")
            + stat("shed_timeout")
            + stat("shed_body_too_large"),
    );

    let out = std::env::var("DFEP_SERVE_OUT")
        .unwrap_or_else(|_| "BENCH_serve.json".to_string());
    let out_path = std::path::Path::new(&out);
    match sink.write(out_path) {
        Ok(()) => println!("\nwrote {}", out_path.display()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", out_path.display()),
    }
}
