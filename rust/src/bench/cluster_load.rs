//! `cluster_load`: end-to-end bench of the distributed runtime.
//!
//! Runs the real coordinator + in-process workers (threads over
//! loopback TCP — a bench binary must not respawn itself) through a
//! clean partitioning + SSSP run, a kill-and-recover run, and a
//! seeded chaos run under a wire fault plan (owners must reproduce
//! the clean run bit-for-bit in all three),
//! reporting round latency, wire bytes per phase (measured vs the
//! [`WireModel`](crate::cluster::cost::WireModel) prediction), and
//! recovery wall-clock. Emits `BENCH_cluster.json` (override with
//! `DFEP_CLUSTER_OUT`), the artifact CI uploads and diffs run over run.

use crate::bench::harness::JsonSink;
use crate::bench::{fmt_f, Table};
use crate::cluster::runtime::{
    run_cluster, ClusterConfig, FailMode, FailureInjection,
};
use crate::util::fault::FaultPlan;

/// Nearest-rank percentile of an ascending-sorted sample.
fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Run the cluster bench; `quick` is the CI smoke shape.
pub fn cluster_load_with(quick: bool) {
    let dataset = if quick {
        "plc:n=400,m=4,p=0.3"
    } else {
        "plc:n=2000,m=8,p=0.3"
    };
    let cfg = ClusterConfig {
        workers: 3,
        k: 8,
        seed: 1,
        dataset: dataset.into(),
        checkpoint_every: 4,
        sssp_source: Some(0),
        in_process: true,
        ..ClusterConfig::default()
    };
    println!(
        "cluster_load: {} workers on {dataset}, k={}, checkpoint every {} \
         rounds",
        cfg.workers, cfg.k, cfg.checkpoint_every
    );

    let rep = run_cluster(&cfg).expect("clean cluster run");
    assert_eq!(rep.recoveries, 0, "clean run must not recover");
    let mut round_ms = rep.round_ms.clone();
    round_ms.sort_by(f64::total_cmp);
    let rounds = rep.partition.rounds as f64;
    let total_bytes = rep.measured.total() as f64;
    println!(
        "clean: {} rounds, round p50 {} ms / p99 {} ms, {} B/round",
        rep.partition.rounds,
        fmt_f(percentile(&round_ms, 0.50)),
        fmt_f(percentile(&round_ms, 0.99)),
        fmt_f(total_bytes / rounds.max(1.0))
    );

    let mut t = Table::new(&["phase", "measured_B", "predicted_B", "ratio"]);
    let phases = [
        ("load", rep.measured.load, rep.predicted.load),
        ("control", rep.measured.control, rep.predicted.control),
        ("bids_up", rep.measured.bids_up, rep.predicted.bids_up),
        ("bids_down", rep.measured.bids_down, rep.predicted.bids_down),
        ("checkpoint", rep.measured.checkpoint, rep.predicted.checkpoint),
        ("merge", rep.measured.merge, rep.predicted.merge),
        ("sssp", rep.measured.sssp, rep.predicted.sssp),
    ];
    for (name, m, p) in phases {
        t.row(&[
            name.to_string(),
            (m as f64).to_string(),
            fmt_f(p),
            fmt_f(m as f64 / p.max(1.0)),
        ]);
    }

    // the recovery path: kill one worker mid-run, time the rollback
    let fail_cfg = ClusterConfig {
        fail: Some(FailureInjection {
            rank: 1,
            round: 4,
            mode: FailMode::Kill,
        }),
        ..cfg.clone()
    };
    let frep = run_cluster(&fail_cfg).expect("recovered cluster run");
    assert_eq!(frep.recoveries, 1, "the injected kill must be recovered");
    assert_eq!(
        frep.partition.owner, rep.partition.owner,
        "recovery must reproduce the clean owners bit-for-bit"
    );
    let recovery_ms: f64 = frep.recovery_ms.iter().sum();
    println!(
        "recovery: {} ms respawn+rollback, {} B recovery traffic, owners \
         reproduced",
        fmt_f(recovery_ms),
        frep.measured.recovery
    );

    // the chaos path: the same run under a seeded wire fault plan —
    // the owners must still come out bit-identical to the clean run
    let plan = FaultPlan::parse(
        "fault:seed=17,drop=0.005,corrupt=0.003,short_read=0.003",
    )
    .expect("chaos plan");
    let chaos_cfg = ClusterConfig {
        fault: Some(plan),
        checkpoint_every: 2,
        max_recoveries: 64,
        ..cfg.clone()
    };
    let crep = run_cluster(&chaos_cfg).expect("chaos cluster run");
    assert_eq!(
        crep.partition.owner, rep.partition.owner,
        "chaos run must reproduce the clean owners bit-for-bit"
    );
    let injected = crep.faults;
    println!(
        "chaos: {} faults absorbed ({} drops, {} corruptions, {} short \
         reads), {} recoveries, owners reproduced",
        injected.total(),
        injected.drops,
        injected.corruptions,
        injected.short_reads,
        crep.recoveries
    );

    let mut sink = JsonSink::new();
    sink.text("bench", "cluster_load");
    sink.num("quick", if quick { 1.0 } else { 0.0 });
    sink.text("dataset", dataset);
    sink.num("workers", cfg.workers as f64);
    sink.num("k", cfg.k as f64);
    sink.num("rounds", rounds);
    sink.num("round_p50_ms", percentile(&round_ms, 0.50));
    sink.num("round_p99_ms", percentile(&round_ms, 0.99));
    sink.num("bytes_total", total_bytes);
    sink.num("bytes_per_round", total_bytes / rounds.max(1.0));
    for (name, m, p) in phases {
        sink.num(&format!("bytes_{name}"), m as f64);
        sink.num(&format!("predicted_{name}"), p);
    }
    sink.num("predicted_total", rep.predicted.total());
    sink.num("recovery_count", frep.recoveries as f64);
    sink.num("recovery_ms", recovery_ms);
    sink.num("recovery_bytes", frep.measured.recovery as f64);
    sink.num("chaos_faults_total", injected.total() as f64);
    sink.num("chaos_drops", injected.drops as f64);
    sink.num("chaos_delays", injected.delays as f64);
    sink.num("chaos_corruptions", injected.corruptions as f64);
    sink.num("chaos_short_reads", injected.short_reads as f64);
    sink.num("chaos_torn_writes", injected.torn_writes as f64);
    sink.num("chaos_recoveries", crep.recoveries as f64);
    sink.num(
        "chaos_recovery_ms",
        crep.recovery_ms.iter().sum::<f64>(),
    );

    let out = std::env::var("DFEP_CLUSTER_OUT")
        .unwrap_or_else(|_| "BENCH_cluster.json".to_string());
    let out_path = std::path::Path::new(&out);
    match sink.write(out_path) {
        Ok(()) => println!("\nwrote {}", out_path.display()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", out_path.display()),
    }
}
