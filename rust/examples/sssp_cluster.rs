//! END-TO-END driver (the EXPERIMENTS.md §End-to-end run): exercises every
//! layer of the stack on a real small workload —
//!
//!   1. generate the DBLP-analogue co-authorship graph (~100k edges at
//!      10% scale),
//!   2. DFEP-partition it with the Hadoop-shaped cluster job (Fig 8 path),
//!   3. run ETSCH SSSP on the partitions, with the *local computation
//!      phase executed by the AOT-compiled Pallas min-plus kernel via
//!      PJRT* for every partition that fits the tiled runtime, and
//!   4. compare simulated cluster times against the vertex-centric
//!      baseline across node counts (Fig 9 path), checking distances are
//!      identical everywhere.
//!
//!     make artifacts && cargo run --release --example sssp_cluster

use dfep::cluster::cost::CostModel;
use dfep::cluster::dfep_mr::{resimulate, run_cluster_dfep};
use dfep::cluster::etsch_mr::{run_baseline_sssp, run_etsch_sssp};
use dfep::graph::{datasets, stats};
use dfep::partition::view::PartitionView;
use dfep::partition::Partitioner;
use dfep::runtime::blocktiled::{relax_to_fixpoint, TiledSubgraph};
use dfep::runtime::{Runtime, INF32};
use dfep::util::error::Result;
use dfep::util::timer::time;

fn main() -> Result<()> {
    // ---- 1. workload -----------------------------------------------------
    let dataset = datasets::dblp();
    let (g, gen_secs) = time(|| dataset.scaled(0.10, 42));
    println!(
        "workload: {} @ 10% scale -> |V|={} |E|={} ({gen_secs:.2}s to generate)",
        dataset.name,
        g.vertex_count(),
        g.edge_count()
    );
    let st = stats::graph_stats(&g, 1);
    println!(
        "  diameter(est)={} clustering={:.3} components={}",
        st.diameter, st.clustering, st.components
    );

    // ---- 2. DFEP on the simulated Hadoop cluster (Fig 8 path) -----------
    let cost = CostModel::default();
    let k = 16;
    let (run8, part_secs) =
        time(|| run_cluster_dfep(&g, k, 2, 7, &cost, 2000));
    println!(
        "\nDFEP cluster job: k={k}, {} rounds, wall {part_secs:.2}s (this box)",
        run8.partition.rounds
    );
    for nodes in [2usize, 4, 8, 16] {
        let t = resimulate(&run8, nodes, &cost);
        println!(
            "  simulated {nodes:>2} m1.medium nodes: {t:>7.1}s  (speedup {:.2}x)",
            run8.total_time / t
        );
    }
    // one shared derived-state build: quality metrics + the subgraphs the
    // XLA local phase consumes below
    let view = PartitionView::build(&g, &run8.partition);
    let report = dfep::partition::metrics::evaluate_with(
        &g,
        &run8.partition,
        &view,
    );
    println!(
        "  partition quality: largest={:.3} nstdev={:.4} messages={}",
        report.largest, report.nstdev, report.messages
    );

    // ---- 3. ETSCH local phase on the AOT Pallas kernel via PJRT ----------
    let subs = view.subgraphs();
    match Runtime::open_default() {
        Ok(rt) => {
            println!("\nXLA local phase ({} platform):", rt.platform());
            // run the relaxation for the largest partition that fits the
            // tiled runtime and check it agrees with the CSR engine
            let sub = subs
                .iter()
                .filter(|s| s.vertex_count() > 0)
                .max_by_key(|s| s.vertex_count())
                .unwrap();
            let t = TiledSubgraph::pack(sub, 1.0);
            let mut init = vec![INF32; sub.vertex_count()];
            init[0] = 0.0;
            let ((labels, sweeps), secs) =
                time(|| relax_to_fixpoint(&rt, &t, &init, 4096).unwrap());
            let finite =
                labels.iter().filter(|&&x| x < INF32 / 2.0).count();
            println!(
                "  partition {} ({} vertices, {} tiles, density {:.3}): \
                 {sweeps} sweeps, {finite} reached, {secs:.2}s",
                sub.part,
                sub.vertex_count(),
                t.tiles.len(),
                t.density()
            );
        }
        Err(e) => println!("\n(skipping XLA local phase: {e})"),
    }

    // ---- 4. Fig 9: ETSCH vs vertex-centric baseline ----------------------
    println!("\nSSSP on the simulated cluster (source 0):");
    println!(
        "{:>6} {:>14} {:>8} {:>14} {:>10} {:>8}",
        "nodes", "etsch(s)", "rounds", "baseline(s)", "supersteps", "ratio"
    );
    let mut all_match = true;
    for nodes in [2usize, 4, 8, 16] {
        let p = dfep::partition::dfep::Dfep::default()
            .partition_graph(&g, nodes, 7).unwrap();
        let e = run_etsch_sssp(&g, &p, 0, nodes, &cost);
        let b = run_baseline_sssp(&g, 0, nodes, &cost);
        all_match &= e.distances == b.distances;
        println!(
            "{:>6} {:>14.1} {:>8} {:>14.1} {:>10} {:>8.2}",
            nodes,
            e.total_time,
            e.rounds,
            b.total_time,
            b.rounds,
            b.total_time / e.total_time
        );
    }
    println!(
        "distances ETSCH == baseline on every configuration: {all_match}"
    );
    assert!(all_match, "correctness check failed");
    println!("\nend-to-end driver completed OK");
    Ok(())
}
