//! Print the Table II/III rows for the synthetic dataset analogues next
//! to the paper's published values (the calibration check).
//!
//!     cargo run --release --example datasets            # 10% scale
//!     DFEP_SCALE=1.0 cargo run --release --example datasets   # full

use dfep::bench::Table;
use dfep::graph::{datasets, stats};

fn main() {
    let scale: f64 = std::env::var("DFEP_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.10);
    println!("scale = {scale} (set DFEP_SCALE=1.0 for the full-size check)");
    let mut table = Table::new(&[
        "dataset", "V(paper)", "V(gen)", "E(paper)", "E(gen)", "D(paper)",
        "D(gen)", "CC(paper)", "CC(gen)",
    ]);
    for d in datasets::simulation_datasets()
        .into_iter()
        .chain(datasets::ec2_datasets())
    {
        let g = if scale >= 1.0 {
            d.generate(42)
        } else {
            d.scaled(scale, 42)
        };
        let s = stats::graph_stats(&g, 1);
        table.row(&[
            d.name.to_string(),
            d.paper.v.to_string(),
            s.vertices.to_string(),
            d.paper.e.to_string(),
            s.edges.to_string(),
            d.paper.d.to_string(),
            s.diameter.to_string(),
            format!("{:.2e}", d.paper.cc),
            format!("{:.2e}", s.clustering),
        ]);
    }
    if scale < 1.0 {
        println!(
            "\nnote: V/E scale with the factor; diameter and clustering are \
             structural and stay comparable for small-world models (roads \
             shrink like sqrt(scale))."
        );
    }
}
