//! Compare every registered partitioner on a small-world and a
//! road-network graph — the Fig-7 story at example scale, driven entirely
//! through the coordinator facade (`PartitionRequest -> RunReport`).
//!
//!     cargo run --release --example partition_compare

use dfep::bench::Table;
use dfep::coordinator::runs::PartitionRequest;
use dfep::graph::datasets;
use dfep::partition::{registry, spec};

fn main() -> dfep::util::error::Result<()> {
    for (name, ds) in
        [("ASTROPH@5%", "astroph"), ("USROADS@5%", "usroads")]
    {
        let d = datasets::by_name(ds).expect("known dataset");
        let g = d.scaled(0.05, 42);
        println!(
            "\n=== {name}: |V|={} |E|={} ===",
            g.vertex_count(),
            g.edge_count()
        );
        let mut table = Table::new(&[
            "algo", "rounds", "largest", "nstdev", "messages", "gain",
        ]);
        for entry in registry::all() {
            let req = PartitionRequest::of(spec::default_spec(entry))
                .k(20)
                .seed(1)
                .gain_samples(3);
            let res = req.execute_on(&g)?;
            let r = &res.metrics;
            table.row(&[
                res.spec.clone(),
                r.rounds.to_string(),
                format!("{:.3}", r.largest),
                format!("{:.4}", r.nstdev),
                r.messages.to_string(),
                format!("{:.3}", res.gain.unwrap_or(0.0)),
            ]);
        }
    }
    println!(
        "\nExpected shapes (paper Fig 7): DFEP/DFEPC more balanced than \
         JaBeJa on small-world; JaBeJa needs ~10x the messages on roads."
    );
    Ok(())
}
