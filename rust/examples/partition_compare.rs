//! Compare every partitioner on a small-world and a road-network graph —
//! the Fig-7 story at example scale.
//!
//!     cargo run --release --example partition_compare

use dfep::bench::Table;
use dfep::coordinator::runs::{run, PartitionerKind, RunConfig};
use dfep::graph::datasets;

fn main() {
    for (name, spec) in
        [("ASTROPH@5%", "astroph"), ("USROADS@5%", "usroads")]
    {
        let d = datasets::by_name(spec).unwrap();
        let g = d.scaled(0.05, 42);
        println!(
            "\n=== {name}: |V|={} |E|={} ===",
            g.vertex_count(),
            g.edge_count()
        );
        let mut table = Table::new(&[
            "algo", "rounds", "largest", "nstdev", "messages", "gain",
        ]);
        for &kind in PartitionerKind::all() {
            let cfg = RunConfig {
                partitioner: kind,
                k: 20,
                seed: 1,
                gain_samples: 3,
            };
            let res = run(&g, &cfg);
            let r = &res.report;
            table.row(&[
                format!("{kind:?}"),
                r.rounds.to_string(),
                format!("{:.3}", r.largest),
                format!("{:.4}", r.nstdev),
                r.messages.to_string(),
                format!("{:.3}", res.gain.unwrap()),
            ]);
        }
    }
    println!(
        "\nExpected shapes (paper Fig 7): DFEP/DFEPC more balanced than \
         JaBeJa on small-world; JaBeJa needs ~10x the messages on roads."
    );
}
