//! The XLA path end to end: DFEP funding rounds executed by the AOT
//! `funding_step` artifact (L2 JAX), and the ETSCH local phase executed by
//! the tiled Pallas min-plus kernel (L1) — both loaded from HLO text via
//! PJRT, no python at runtime.
//!
//!     make artifacts && cargo run --release --example xla_engine

use dfep::graph::generators::GraphKind;
use dfep::partition::view::PartitionView;
use dfep::partition::{dfep::Dfep, metrics, Partitioner};
use dfep::runtime::blocktiled::{relax_to_fixpoint, TiledSubgraph};
use dfep::runtime::xla_engine::XlaDfep;
use dfep::runtime::{Runtime, INF32};
use dfep::util::error::Result;
use dfep::util::timer::time;

fn main() -> Result<()> {
    let rt = Runtime::open_default()?;
    println!("PJRT platform: {}", rt.platform());
    println!("artifacts:");
    for name in rt.manifest().artifacts.keys() {
        println!("  {name}");
    }

    // a graph that fits the small funding artifact (E <= 4096)
    let g = GraphKind::PowerlawCluster { n: 600, m: 3, p: 0.35 }
        .generate(11);
    println!(
        "\ngraph: |V|={} |E|={}",
        g.vertex_count(),
        g.edge_count()
    );

    // --- DFEP with XLA-offloaded funding rounds --------------------------
    let k = 8;
    let (px, tx) =
        time(|| XlaDfep::default().partition(&rt, &g, k, 3).unwrap());
    let (pr, tr) = time(|| Dfep::default().partition_graph(&g, k, 3).unwrap());
    // one shared derivation per partition: metrics here, subgraphs below
    let view = PartitionView::build(&g, &px);
    let rx = metrics::evaluate_with(&g, &px, &view);
    let rr = metrics::evaluate(&g, &pr);
    println!("\nDFEP engines (k={k}):");
    println!(
        "  XLA  funding_step: {tx:.3}s, {} rounds, nstdev {:.4}, messages {}",
        rx.rounds, rx.nstdev, rx.messages
    );
    println!(
        "  rust reference:    {tr:.3}s, {} rounds, nstdev {:.4}, messages {}",
        rr.rounds, rr.nstdev, rr.messages
    );

    // --- ETSCH local phase on the Pallas kernel --------------------------
    let sub = view
        .subgraphs()
        .iter()
        .max_by_key(|s| s.vertex_count())
        .unwrap();
    let tiled = TiledSubgraph::pack(sub, 1.0);
    let mut init = vec![INF32; sub.vertex_count()];
    init[0] = 0.0;
    let ((labels, sweeps), secs) =
        time(|| relax_to_fixpoint(&rt, &tiled, &init, 1024).unwrap());
    println!(
        "\nPallas min-plus local phase on partition {} \
         ({} vertices, {} tiles):",
        sub.part,
        sub.vertex_count(),
        tiled.tiles.len()
    );
    println!(
        "  {sweeps} sweeps in {secs:.3}s; {} vertices reached",
        labels.iter().filter(|&&x| x < INF32 / 2.0).count()
    );
    Ok(())
}
