//! The ETSCH algorithm zoo on one DFEP-partitioned graph: SSSP, connected
//! components, Luby MIS, PageRank, k-core, label propagation and sampled
//! betweenness centrality — the paper's §III/§VII claim that the
//! init/local/aggregate model covers "the most common properties of
//! graphs", made executable.
//!
//!     cargo run --release --example algorithms

use dfep::etsch::{
    betweenness::{brandes_ref, etsch_betweenness},
    cc::ConnectedComponents,
    kcore::{kcore_ref, KCore},
    labelprop::LabelPropagation,
    mis::{validate_mis, LubyMis, Status},
    pagerank::PageRank,
    sssp::Sssp,
    Etsch,
};
use dfep::graph::generators::GraphKind;
use dfep::partition::{dfep::Dfep, Partitioner};
use dfep::util::timer::time;

fn main() {
    let g = GraphKind::PowerlawCluster { n: 2_000, m: 5, p: 0.35 }
        .generate(42);
    let k = 6;
    let p = Dfep::default().partition_graph(&g, k, 1).unwrap();
    println!(
        "graph |V|={} |E|={}, DFEP k={k} ({} rounds)",
        g.vertex_count(),
        g.edge_count(),
        p.rounds
    );
    let mut engine = Etsch::new(&g, &p);

    // SSSP
    let (dist, secs) = time(|| engine.run(&mut Sssp::new(0)));
    println!(
        "\nsssp:        {} rounds, ecc(0)={}, {secs:.3}s",
        engine.rounds_executed(),
        dist.iter().filter(|&&d| d != u32::MAX).max().unwrap()
    );

    // connected components
    let (labels, secs) =
        time(|| engine.run(&mut ConnectedComponents::new(7)));
    let ncomp =
        labels.iter().collect::<std::collections::HashSet<_>>().len();
    println!(
        "components:  {} rounds, {ncomp} component(s), {secs:.3}s",
        engine.rounds_executed()
    );

    // Luby MIS
    let (mis, secs) = time(|| engine.run(&mut LubyMis::new(3)));
    let in_set: Vec<bool> =
        mis.iter().map(|s| s.status == Status::InSet).collect();
    validate_mis(&g, &in_set).expect("valid MIS");
    println!(
        "luby MIS:    {} rounds, |S|={}, valid, {secs:.3}s",
        engine.rounds_executed(),
        in_set.iter().filter(|&&b| b).count()
    );

    // PageRank
    let (pr, secs) = time(|| engine.run(&mut PageRank::new(&g, 20)));
    let top = pr
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.rank.partial_cmp(&b.1.rank).unwrap())
        .unwrap();
    println!(
        "pagerank:    20 rounds, top vertex {} (rank {:.5}), {secs:.3}s",
        top.0, top.1.rank
    );

    // k-core
    let kk = 4;
    let (core, secs) = time(|| engine.run(&mut KCore::new(kk)));
    let size = core.iter().filter(|s| s.alive).count();
    let want = kcore_ref(&g, kk).iter().filter(|&&a| a).count();
    assert_eq!(size, want, "k-core mismatch vs sequential peeling");
    println!(
        "{kk}-core:      {} rounds, {size} vertices (== sequential), {secs:.3}s",
        engine.rounds_executed()
    );

    // label propagation
    let (lpa, secs) =
        time(|| engine.run(&mut LabelPropagation::default()));
    let ncommunities =
        lpa.iter().map(|s| s.label).collect::<std::collections::HashSet<_>>().len();
    println!(
        "labelprop:   {} rounds, {ncommunities} communities, {secs:.3}s",
        engine.rounds_executed()
    );

    // sampled betweenness (validated against Brandes on a subsample scale)
    let (bc, secs) = time(|| etsch_betweenness(&g, &p, 32, 9));
    let hub = bc
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    println!("betweenness: 32 sources sampled, top hub {hub}, {secs:.3}s");

    // cross-check on a small induced instance
    let small = GraphKind::ErdosRenyi { n: 80, m: 200 }.generate(5);
    let sp = Dfep::default().partition_graph(&small, 3, 2).unwrap();
    let exact = etsch_betweenness(&small, &sp, 0, 0);
    let oracle = brandes_ref(&small);
    let max_err = exact
        .iter()
        .zip(&oracle)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!(
        "betweenness exact-mode vs Brandes on |V|=80: max abs err {max_err:.2e}"
    );
    assert!(max_err < 1e-6);
}
