//! Fig-6 at example scale: rewire a road network to lower its diameter
//! and watch DFEP's balance, rounds, messages and gain respond.
//!
//!     cargo run --release --example diameter_study

use dfep::bench::Table;
use dfep::etsch::gain::average_gain;
use dfep::graph::{datasets, rewire, stats};
use dfep::partition::{dfep::Dfep, metrics, Partitioner};

fn main() {
    let g0 = datasets::usroads().scaled(0.04, 42);
    println!(
        "base road graph: |V|={} |E|={}",
        g0.vertex_count(),
        g0.edge_count()
    );
    let mut table = Table::new(&[
        "remap%", "diameter", "largest", "nstdev", "rounds", "messages",
        "gain", "disc%",
    ]);
    for frac in [0.0, 0.02, 0.05, 0.1, 0.2, 0.4] {
        let g = rewire::rewire_fraction(&g0, frac, 7);
        let d = stats::diameter_estimate(&g, 4, 1);
        let p = Dfep::default().partition(&g, 20, 1);
        let r = metrics::evaluate(&g, &p);
        let gain = average_gain(&g, &p, 2, 3);
        table.row(&[
            format!("{:.0}", frac * 100.0),
            d.to_string(),
            format!("{:.3}", r.largest),
            format!("{:.4}", r.nstdev),
            r.rounds.to_string(),
            r.messages.to_string(),
            format!("{:.3}", gain),
            format!("{:.0}", r.disconnected * 100.0),
        ]);
    }
    println!(
        "\nExpected shapes (paper Fig 6): balance degrades and rounds rise \
         with diameter; messages fall; gain rises."
    );
}
