//! Fig-6 at example scale: rewire a road network to lower its diameter
//! and watch DFEP's balance, rounds, messages and gain respond.
//!
//!     cargo run --release --example diameter_study

use dfep::bench::Table;
use dfep::coordinator::runs::PartitionRequest;
use dfep::graph::{datasets, rewire, stats};

fn main() -> dfep::util::error::Result<()> {
    let g0 = datasets::usroads().scaled(0.04, 42);
    println!(
        "base road graph: |V|={} |E|={}",
        g0.vertex_count(),
        g0.edge_count()
    );
    let mut table = Table::new(&[
        "remap%", "diameter", "largest", "nstdev", "rounds", "messages",
        "gain", "disc%",
    ]);
    for frac in [0.0, 0.02, 0.05, 0.1, 0.2, 0.4] {
        let g = rewire::rewire_fraction(&g0, frac, 7);
        let d = stats::diameter_estimate(&g, 4, 1);
        // one facade run per rewired instance: metrics + gain off one
        // shared view build
        let res = PartitionRequest::new("dfep")?
            .k(20)
            .seed(1)
            .gain_samples(2)
            .execute_on(&g)?;
        let r = &res.metrics;
        let gain = res.gain.unwrap_or(0.0);
        table.row(&[
            format!("{:.0}", frac * 100.0),
            d.to_string(),
            format!("{:.3}", r.largest),
            format!("{:.4}", r.nstdev),
            r.rounds.to_string(),
            r.messages.to_string(),
            format!("{:.3}", gain),
            format!("{:.0}", r.disconnected * 100.0),
        ]);
    }
    println!(
        "\nExpected shapes (paper Fig 6): balance degrades and rounds rise \
         with diameter; messages fall; gain rises."
    );
    Ok(())
}
