//! Quickstart: one `PartitionRequest` through the coordinator facade —
//! partition a small-world graph with DFEP, get the paper's quality
//! metrics and an attached ETSCH SSSP workload back in one `RunReport` —
//! then reuse the partition for a second ETSCH computation.
//!
//!     cargo run --release --example quickstart

use dfep::coordinator::runs::{resolve_graph, PartitionRequest, Workload};
use dfep::etsch::{cc::ConnectedComponents, Etsch};
use dfep::util::error::Result;

fn main() -> Result<()> {
    // 1. one request: dataset spec + partitioner spec + k + seed +
    //    workload; the facade resolves, partitions, evaluates and runs
    //    the workload off one shared PartitionView build
    let req = PartitionRequest::new("dfep")?
        .dataset("plc:n=5000,m=8,p=0.4")
        .k(8)
        .seed(1)
        .workload(Workload::Sssp { source: 0 });
    let res = req.execute()?;

    let r = &res.metrics;
    println!(
        "{} on {} (k = {}) in {:.3}s:",
        res.spec, res.dataset, res.k, res.timings.partition_secs
    );
    println!("  rounds        {}", r.rounds);
    println!("  largest part  {:.3} (1.0 = perfectly balanced)", r.largest);
    println!("  nstdev        {:.4}", r.nstdev);
    println!("  messages      {} (sum of frontier replicas)", r.messages);
    println!("  disconnected  {:.1}%", r.disconnected * 100.0);

    // 2. the attached ETSCH workload came back with the report
    let w = res.workload.as_ref().expect("workload was requested");
    println!(
        "\nETSCH {}: {} rounds, {} reached, {} messages, {:.3}s",
        w.name, w.rounds, w.reached, w.messages, w.secs
    );

    // the whole report serializes through the crate's flat JSON writer
    println!("\nas JSON:\n{}", res.to_json());

    // 3. the partition itself is in the report — run a second ETSCH
    //    computation on it (connected components)
    let g = resolve_graph(&res.dataset, 42)?;
    let mut engine = Etsch::new(&g, &res.partition);
    let labels = engine.run(&mut ConnectedComponents::new(7));
    let distinct: std::collections::HashSet<_> = labels.iter().collect();
    println!(
        "ETSCH connected components: {} rounds, {} component(s)",
        engine.rounds_executed(),
        distinct.len()
    );
    Ok(())
}
