//! Quickstart: partition a small-world graph with DFEP, inspect the
//! paper's quality metrics, then run an ETSCH computation on the result.
//!
//!     cargo run --release --example quickstart

use dfep::etsch::{cc::ConnectedComponents, sssp::Sssp, Etsch};
use dfep::graph::generators::GraphKind;
use dfep::partition::view::PartitionView;
use dfep::partition::{dfep::Dfep, metrics, Partitioner};

fn main() {
    // 1. a graph — here a synthetic collaboration-network lookalike
    let g = GraphKind::PowerlawCluster { n: 5_000, m: 8, p: 0.4 }
        .generate(42);
    println!(
        "graph: |V| = {}, |E| = {}",
        g.vertex_count(),
        g.edge_count()
    );

    // 2. DFEP edge partitioning into k = 8 parts
    let k = 8;
    let (part, secs) =
        dfep::util::timer::time(|| Dfep::default().partition(&g, k, 1));
    // derive the partition's shared state (edge CSRs, replica table,
    // frontier flags) once; metrics and ETSCH both read from it
    let view = PartitionView::build(&g, &part);
    let report = metrics::evaluate_with(&g, &part, &view);
    println!("\nDFEP (k = {k}) in {secs:.3}s:");
    println!("  rounds        {}", report.rounds);
    println!("  largest part  {:.3} (1.0 = perfectly balanced)", report.largest);
    println!("  nstdev        {:.4}", report.nstdev);
    println!("  messages      {} (sum of frontier replicas)", report.messages);
    println!("  disconnected  {:.1}%", report.disconnected * 100.0);

    // 3. ETSCH: single-source shortest paths over the edge partitions
    // (sharing the view built above — no re-derivation)
    let mut engine = Etsch::from_view(&g, &view);
    let dist = engine.run(&mut Sssp::new(0));
    let reached = dist.iter().filter(|&&d| d != u32::MAX).count();
    println!(
        "\nETSCH sssp: {} rounds, {} reached, max dist {}",
        engine.rounds_executed(),
        reached,
        dist.iter().filter(|&&d| d != u32::MAX).max().unwrap()
    );

    // compare with the vertex-centric baseline (one hop per superstep)
    let base = dfep::etsch::vertex_baseline::bsp_sssp(&g, 0);
    println!(
        "baseline:   {} supersteps  ->  gain = {:.2}",
        base.supersteps,
        1.0 - engine.rounds_executed() as f64 / base.supersteps as f64
    );

    // 4. ETSCH: connected components on the same partitioning
    let labels = engine.run(&mut ConnectedComponents::new(7));
    let distinct: std::collections::HashSet<_> = labels.iter().collect();
    println!(
        "\nETSCH connected components: {} rounds, {} component(s)",
        engine.rounds_executed(),
        distinct.len()
    );
}
