//! Streaming-subsystem invariants (see DESIGN.md "Streaming ingestion &
//! partitioners"):
//!
//! (a) chunked-from-disk and in-memory ingestion produce bit-identical
//!     partitions for every streaming algorithm;
//! (b) HDRF / DBH / restream are bit-identical across 1/2/8 pool threads
//!     and across ingestion chunk sizes {64, 4096, |E|};
//! (c) restreaming refinement never increases the replication factor of
//!     its input assignment;
//! plus the acceptance bar: HDRF's replication factor is no worse than
//! the materializing StreamingGreedy on the calibrated power-law
//! datasets at k in {8, 32}.

use dfep::graph::stream::{EdgeStream, FileEdgeStream, MemoryEdgeStream};
use dfep::graph::{datasets, generators::GraphKind, io, Graph};
use dfep::partition::spec::PartitionerSpec;
use dfep::partition::streaming::{stream_stats, Hdrf, Restream};
use dfep::partition::{
    baselines::RandomEdge, fennel::StreamingGreedy, metrics, registry,
    EdgePartition, PartitionInput, Partitioner, StreamInput,
};
use dfep::testing::prop::forall;
use dfep::util::pool;

/// Every streaming-native registry entry, built with default params —
/// the unified-trait counterpart of the old hand-kept streamer list.
fn streamers() -> Vec<(&'static str, Box<dyn Partitioner>)> {
    let out: Vec<_> = registry::all()
        .iter()
        .filter(|e| e.streaming_native)
        .map(|e| (e.name, dfep::partition::spec::default_spec(e).build()))
        .collect();
    assert_eq!(out.len(), 3, "hdrf/dbh/restream expected");
    out
}

/// Rebuild a streamer with a specific ingestion chunk size through the
/// same spec grammar the CLI uses.
fn with_chunk(name: &str, chunk: usize) -> Box<dyn Partitioner> {
    PartitionerSpec::parse(&format!("{name}:chunk={chunk}"))
        .unwrap_or_else(|e| panic!("{name}: {e}"))
        .build()
}

/// Run the unified trait's stream arm.
fn stream_partition(
    p: &dyn Partitioner,
    s: &mut dyn EdgeStream,
    k: usize,
    seed: u64,
) -> EdgePartition {
    p.partition(PartitionInput::Stream(StreamInput::new(s)), k, seed)
        .expect("stream partition failed")
}

/// Total replicas: Σ_v |{parts containing v}| — the replication factor's
/// numerator, via the independent adjacency-stamp derivation.
fn replicas(g: &Graph, p: &EdgePartition) -> usize {
    p.vertex_multiplicity(g).iter().map(|&m| m as usize).sum()
}

#[test]
fn chunked_file_ingestion_identical_to_in_memory() {
    let g = GraphKind::PowerlawCluster { n: 1200, m: 4, p: 0.3 }.generate(11);
    let dir = std::env::temp_dir().join("dfep_streaming_invariants");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("chunked.txt");
    io::write_edge_list(&g, &path).unwrap();
    let m = g.edge_count();

    for (name, p) in streamers() {
        let mut mem = MemoryEdgeStream::from_graph(&g);
        let base = stream_partition(p.as_ref(), &mut mem, 8, 5);
        base.validate(&g).unwrap();
        for chunk in [64usize, 4096, m] {
            let retuned = with_chunk(name, chunk);
            let mut file = FileEdgeStream::open(&path).unwrap();
            let got = stream_partition(retuned.as_ref(), &mut file, 8, 5);
            assert_eq!(
                got.owner, base.owner,
                "{name}: disk chunk={chunk} differs from in-memory"
            );
            assert_eq!(got.rounds, base.rounds, "{name}: rounds");
        }
    }
}

#[test]
fn streaming_partitions_bit_identical_across_1_2_8_threads() {
    let g = GraphKind::PowerlawCluster { n: 1500, m: 5, p: 0.3 }.generate(3);
    let m = g.edge_count();
    for (name, _) in streamers() {
        let base = pool::with_threads(1, || {
            let mut s = MemoryEdgeStream::from_graph(&g);
            stream_partition(with_chunk(name, 4096).as_ref(), &mut s, 8, 7)
        });
        for threads in [2usize, 8] {
            for chunk in [64usize, 4096, m] {
                let got = pool::with_threads(threads, || {
                    let mut s = MemoryEdgeStream::from_graph(&g);
                    stream_partition(
                        with_chunk(name, chunk).as_ref(),
                        &mut s,
                        8,
                        7,
                    )
                });
                assert_eq!(
                    got.owner, base.owner,
                    "{name}: {threads} threads, chunk {chunk}"
                );
            }
        }
    }
}

#[test]
fn restream_refinement_never_increases_replication() {
    forall(8, |gen| {
        let graph = gen.any_graph(12, 140);
        let k = gen.int(2, 7);
        let prev_seed: u64 = gen.rng.next_u64();
        let prev = RandomEdge.partition_graph(&graph, k, prev_seed).unwrap();
        let before = replicas(&graph, &prev);
        let mut s = MemoryEdgeStream::from_graph(&graph);
        let refined =
            Restream::default().refine(&mut s, k, &prev.owner).unwrap();
        refined.validate(&graph).unwrap();
        let after = replicas(&graph, &refined);
        assert!(
            after <= before,
            "replicas rose {before} -> {after} (k={k})"
        );
    });
}

#[test]
fn restream_improves_what_hdrf_started() {
    // the full pipeline (HDRF + refine) should not be worse than HDRF
    // alone — the refinement accepts only non-increasing moves
    let g = datasets::astroph().scaled(0.1, 42);
    let hdrf = Hdrf::default().partition_graph(&g, 8, 1).unwrap();
    let full = Restream::default().partition_graph(&g, 8, 1).unwrap();
    full.validate(&g).unwrap();
    assert!(
        replicas(&g, &full) <= replicas(&g, &hdrf),
        "restream {} > hdrf {}",
        replicas(&g, &full),
        replicas(&g, &hdrf)
    );
}

#[test]
fn hdrf_replication_no_worse_than_streaming_greedy_at_k8_and_k32() {
    // acceptance bar: on the calibrated synthetic power-law dataset the
    // degree-aware ingest-time greedy must match or beat the
    // materializing streaming baseline on replication
    let g = datasets::astroph().scaled(0.2, 42);
    for k in [8usize, 32] {
        let hdrf = Hdrf::default().partition_graph(&g, k, 1).unwrap();
        hdrf.validate(&g).unwrap();
        let greedy =
            StreamingGreedy::default().partition_graph(&g, k, 1).unwrap();
        let (rh, rg) = (replicas(&g, &hdrf), replicas(&g, &greedy));
        assert!(
            rh <= rg,
            "k={k}: HDRF replicas {rh} exceed StreamingGreedy {rg}"
        );
        // and it must stay a usable partition, not a replication-only
        // degenerate: every part nonempty, balance within 2x ideal
        let r = metrics::evaluate(&g, &hdrf);
        assert!(r.largest < 2.0, "k={k}: largest {}", r.largest);
        assert!(
            hdrf.sizes().iter().all(|&s| s > 0),
            "k={k}: empty part"
        );
    }
}

#[test]
fn streaming_quality_evaluates_through_partition_view() {
    // the streaming owner vector plugs straight into the shared derived
    // state path, and the bounded-memory stats agree with it
    let g = datasets::astroph().scaled(0.05, 42);
    for (name, p) in streamers() {
        let mut s = MemoryEdgeStream::from_graph(&g);
        let part = stream_partition(p.as_ref(), &mut s, 6, 2);
        let report = metrics::evaluate(&g, &part);
        assert!(report.largest >= 1.0, "{name}");
        let st = stream_stats(&mut s, &part.owner, 6, 1024).unwrap();
        assert_eq!(st.edges, g.edge_count(), "{name}");
        assert_eq!(&st.sizes[..], &part.sizes()[..], "{name}");
        assert_eq!(st.replicas, replicas(&g, &part), "{name}");
    }
}
