//! Allocation budget for the refinement engine: post-convergence rounds
//! must perform **zero** heap allocations — the persistent
//! `RefineScratch` (per-shard proposal buffers, gain buckets, blocked
//! queue) and the fixed-capacity count CSR are the whole point, matching
//! the PR5 DFEP budget contract (`tests/alloc_budget.rs`).
//!
//! Same harness: a counting `#[global_allocator]` (cfg-gated off under
//! miri), exactly one test in its own binary, and a single-thread pool
//! so the count reflects the engine's buffers rather than the pool's
//! channel transport.
//!
//! The measured window differs from the DFEP test on purpose: refinement
//! rounds shrink as the partition settles, so a trailing-quarter window
//! over the *improving* phase would not be provably allocation-free.
//! Instead the engine is driven to its fixed point (a round that applies
//! nothing — every later round performs the identical scan against
//! identical state), and then eight post-convergence rounds are each
//! asserted to allocate zero.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use dfep::graph::generators::GraphKind;
use dfep::partition::refine::RefineEngine;
use dfep::partition::spec::PartitionerSpec;
use dfep::util::pool;

/// Counts allocation events (`alloc` + growing `realloc`); frees are not
/// counted — the budget is about acquiring memory in steady state.
struct CountingAlloc;

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc(layout) }
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc_zeroed(layout) }
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.realloc(ptr, layout, new_size) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }
}

#[cfg(not(miri))]
#[global_allocator]
static GLOBAL_COUNTER: CountingAlloc = CountingAlloc;

fn alloc_count() -> u64 {
    ALLOCATIONS.load(Ordering::Relaxed)
}

#[test]
#[cfg_attr(miri, ignore = "the counting allocator is disabled under miri")]
fn refine_steady_state_rounds_allocate_zero() {
    pool::with_threads(1, || {
        // a random base partition maximizes early move volume, so the
        // scratch buffers reach their high-water capacity fast
        let g = GraphKind::PowerlawCluster { n: 1_000, m: 4, p: 0.3 }
            .generate(42);
        let base = PartitionerSpec::parse("random")
            .unwrap()
            .build()
            .partition_graph(&g, 8, 5)
            .unwrap();
        let a0 = alloc_count();
        let mut eng = RefineEngine::new(&g, &base, 0.05);
        assert!(
            alloc_count() > a0,
            "engine construction allocated nothing — counting allocator \
             inactive?"
        );
        // drive to the fixed point; each improving round lowers the
        // replica total by >= 1, so this is guaranteed to terminate
        let budget = eng.total_replicas() + 4;
        let mut converged = false;
        for _ in 0..budget {
            if eng.round(&g) == 0 {
                converged = true;
                break;
            }
        }
        assert!(converged, "engine never reached its fixed point");
        assert!(eng.moves_applied > 0, "warm-up applied no moves");
        // post-convergence rounds re-run the identical scan against
        // identical state at settled capacity: zero allocations, each
        for i in 0..8 {
            let before = alloc_count();
            assert_eq!(eng.round(&g), 0);
            assert_eq!(
                alloc_count() - before,
                0,
                "steady-state round {i} allocated"
            );
        }
    });
}
