//! Distributed runtime contract: `cluster::runtime` must produce owners
//! *bit-identical* to the single-process `PartitionRequest` facade — at
//! any worker count, and after recovering from an injected mid-round
//! failure (kill or stall) via checkpoint rollback. The measured wire
//! bytes must match the `cost::WireModel` prediction phase by phase.
//!
//! All runs here use `in_process: true`: workers are threads dialing
//! real loopback TCP sockets through the real frame codec, because
//! spawning `current_exe` from inside a test binary would re-run the
//! test harness instead of `repro worker`.

use dfep::cluster::runtime::{
    run_cluster, ClusterConfig, FailMode, FailureInjection,
};
use dfep::coordinator::runs::{resolve_graph, PartitionRequest};
use dfep::etsch::{sssp::Sssp, Etsch};

const DATASET: &str = "plc:n=400,m=4,p=0.3";
const K: usize = 8;
const SEED: u64 = 3;
const GRAPH_SEED: u64 = 7;

fn base_cfg() -> ClusterConfig {
    ClusterConfig {
        workers: 3,
        k: K,
        seed: SEED,
        spec: "dfep".into(),
        dataset: DATASET.into(),
        graph_seed: GRAPH_SEED,
        checkpoint_every: 4,
        in_process: true,
        ..ClusterConfig::default()
    }
}

/// The single-process reference owners for the same (dataset, spec, k,
/// seed) tuple.
fn facade_owner() -> Vec<u32> {
    PartitionRequest::new("dfep")
        .unwrap()
        .dataset(DATASET)
        .k(K)
        .seed(SEED)
        .graph_seed(GRAPH_SEED)
        .execute()
        .unwrap()
        .partition
        .owner
}

#[test]
fn owners_bit_identical_at_any_worker_count() {
    let reference = facade_owner();
    for workers in [1usize, 2, 4] {
        let cfg = ClusterConfig { workers, ..base_cfg() };
        let rep = run_cluster(&cfg).unwrap();
        assert_eq!(rep.recoveries, 0);
        assert_eq!(rep.workers, workers);
        assert_eq!(
            rep.partition.owner, reference,
            "{workers}-worker owners diverge from the facade"
        );
    }
}

#[test]
fn killed_worker_recovers_to_identical_owners() {
    let reference = facade_owner();
    let cfg = ClusterConfig {
        fail: Some(FailureInjection {
            rank: 1,
            round: 6,
            mode: FailMode::Kill,
        }),
        ..base_cfg()
    };
    let rep = run_cluster(&cfg).unwrap();
    assert_eq!(rep.recoveries, 1, "the injected kill must be recovered");
    assert_eq!(rep.recovery_ms.len(), 1);
    assert_eq!(
        rep.partition.owner, reference,
        "post-recovery owners diverge from the facade"
    );
    assert!(
        rep.measured.recovery > 0,
        "recovery traffic must be measured"
    );
}

#[test]
fn stalled_worker_times_out_and_recovers() {
    let reference = facade_owner();
    let cfg = ClusterConfig {
        fail: Some(FailureInjection {
            rank: 2,
            round: 3,
            // stalls far longer than the detector's patience
            mode: FailMode::Stall(30_000),
        }),
        worker_timeout_ms: 1_000,
        ..base_cfg()
    };
    let rep = run_cluster(&cfg).unwrap();
    assert_eq!(rep.recoveries, 1, "the stall must trip the read timeout");
    assert_eq!(rep.partition.owner, reference);
}

#[test]
fn distributed_sssp_matches_single_process_etsch() {
    let cfg = ClusterConfig { sssp_source: Some(0), ..base_cfg() };
    let rep = run_cluster(&cfg).unwrap();
    let dist = rep.sssp_dist.expect("sssp phase ran");
    let g = resolve_graph(DATASET, GRAPH_SEED).unwrap();
    let expected = Etsch::new(&g, &rep.partition).run(&mut Sssp::new(0));
    assert_eq!(dist, expected);
}

#[test]
fn wire_model_predicts_measured_bytes() {
    let cfg = ClusterConfig { sssp_source: Some(0), ..base_cfg() };
    let rep = run_cluster(&cfg).unwrap();
    assert_eq!(rep.measured.recovery, 0, "clean run");
    // every byte-exact phase within 10% (they should be exact; the
    // slack keeps the test about the model, not the codec)
    let exact = [
        ("load", rep.measured.load, rep.predicted.load),
        ("control", rep.measured.control, rep.predicted.control),
        ("bids_up", rep.measured.bids_up, rep.predicted.bids_up),
        ("bids_down", rep.measured.bids_down, rep.predicted.bids_down),
        ("merge", rep.measured.merge, rep.predicted.merge),
        ("sssp", rep.measured.sssp, rep.predicted.sssp),
    ];
    for (name, measured, predicted) in exact {
        let m = measured as f64;
        assert!(
            (m - predicted).abs() <= 0.10 * predicted.max(1.0),
            "{name}: measured {measured} vs predicted {predicted:.0}"
        );
    }
    // the checkpoint blob's sparse ledger section is state-dependent
    // and deliberately unmodeled: the prediction is a floor, and the
    // holder entries stay within ~60% of it on this workload
    let (m, p) = (rep.measured.checkpoint as f64, rep.predicted.checkpoint);
    assert!(
        m >= p,
        "checkpoint: measured {m:.0} below the modeled floor {p:.0}"
    );
    assert!(
        m <= 1.6 * p,
        "checkpoint: measured {m:.0} exceeds 1.6x the floor {p:.0}"
    );
}

#[test]
fn persisted_checkpoints_land_on_disk() {
    let dir = std::env::temp_dir().join("dfep_cluster_ckpt_test");
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = ClusterConfig {
        checkpoint_dir: Some(dir.clone()),
        ..base_cfg()
    };
    let rep = run_cluster(&cfg).unwrap();
    // round-0 blobs always exist, one per worker
    for rank in 0..cfg.workers {
        let p = dir.join(format!("ckpt_r0_w{rank}.bin"));
        assert!(p.exists(), "missing {}", p.display());
        assert!(std::fs::metadata(&p).unwrap().len() > 0);
    }
    assert!(rep.shape.checkpoints >= 1);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn config_validation_rejects_nonsense() {
    let bad_workers = ClusterConfig { workers: 0, ..base_cfg() };
    assert!(run_cluster(&bad_workers).is_err());
    let bad_rank = ClusterConfig {
        fail: Some(FailureInjection {
            rank: 9,
            round: 1,
            mode: FailMode::Kill,
        }),
        ..base_cfg()
    };
    assert!(run_cluster(&bad_rank).is_err());
    let bad_algo = ClusterConfig { spec: "hdrf".into(), ..base_cfg() };
    assert!(run_cluster(&bad_algo).is_err());
    let bad_source = ClusterConfig {
        sssp_source: Some(1_000_000),
        ..base_cfg()
    };
    assert!(run_cluster(&bad_source).is_err());
    // a zero worker timeout would make every derived deadline nonsense
    let bad_timeout = ClusterConfig { worker_timeout_ms: 0, ..base_cfg() };
    let err = run_cluster(&bad_timeout).unwrap_err();
    assert_eq!(err.kind(), dfep::util::error::ErrorKind::InvalidRequest);
}
