//! Batch engine equivalence: `BatchRequest::execute_on` must be
//! *bit-identical* to N sequential `PartitionRequest::execute_on` calls —
//! for every registry spec, at any pool thread count, in any variant
//! order. This is the contract that lets the serve layer share one
//! result cache between `/partition` and `/batch`, and lets the figure
//! benches swap their sequential loops for the engine without changing a
//! single reported number.

use dfep::coordinator::batch::{BatchRequest, Variant};
use dfep::coordinator::runs::{RunReport, Workload};
use dfep::graph::generators::GraphKind;
use dfep::graph::Graph;
use dfep::partition::registry;
use dfep::util::pool;

fn graph() -> Graph {
    GraphKind::ErdosRenyi { n: 600, m: 1_800 }.generate(42)
}

/// Every-field bit comparison (floats by `to_bits`, owners exactly).
fn assert_bit_identical(a: &RunReport, b: &RunReport, what: &str) {
    assert_eq!(a.spec, b.spec, "{what}: spec");
    assert_eq!(a.k, b.k, "{what}: k");
    assert_eq!(a.seed, b.seed, "{what}: seed");
    assert_eq!(a.vertices, b.vertices, "{what}: vertices");
    assert_eq!(a.edges, b.edges, "{what}: edges");
    assert_eq!(a.partition.owner, b.partition.owner, "{what}: owners");
    assert_eq!(a.partition.rounds, b.partition.rounds, "{what}: rounds");
    assert_eq!(
        a.metrics.largest.to_bits(),
        b.metrics.largest.to_bits(),
        "{what}: largest"
    );
    assert_eq!(
        a.metrics.nstdev.to_bits(),
        b.metrics.nstdev.to_bits(),
        "{what}: nstdev"
    );
    assert_eq!(a.metrics.messages, b.metrics.messages, "{what}: messages");
    assert_eq!(
        a.metrics.disconnected.to_bits(),
        b.metrics.disconnected.to_bits(),
        "{what}: disconnected"
    );
    assert_eq!(
        a.gain.map(f64::to_bits),
        b.gain.map(f64::to_bits),
        "{what}: gain"
    );
}

/// One variant per (registry spec, k) pair — the full surface the
/// engine must reproduce.
fn registry_variants() -> Vec<Variant> {
    let mut out = Vec::new();
    for entry in registry::all() {
        for k in [2usize, 8] {
            out.push(Variant::new(entry.name, k, 7).unwrap());
        }
    }
    out
}

fn batch_of(variants: Vec<Variant>) -> BatchRequest {
    let mut b = BatchRequest::new("");
    b.variants = variants;
    b
}

#[test]
fn batch_matches_sequential_for_every_registry_spec_at_any_width() {
    let g = graph();
    let breq = batch_of(registry_variants());
    // the baseline: the exact sequential facade loop, one pool thread
    let baseline: Vec<RunReport> = pool::with_threads(1, || {
        breq.variants
            .iter()
            .map(|v| breq.request_for(v).execute_on(&g).unwrap())
            .collect()
    });
    for threads in [1usize, 2, 8] {
        let rep =
            pool::with_threads(threads, || breq.execute_on(&g)).unwrap();
        assert_eq!(rep.reports.len(), baseline.len());
        assert_eq!(rep.lanes, threads.min(breq.variants.len()));
        for (got, want) in rep.reports.iter().zip(&baseline) {
            assert_bit_identical(
                got,
                want,
                &format!("{}@k={} ({} threads)", want.spec, want.k, threads),
            );
        }
    }
}

#[test]
fn variant_order_never_reaches_the_reports() {
    let g = graph();
    let forward = registry_variants();
    // two deterministic reorderings: reversed, and rotated by a third
    let mut shuffles = Vec::new();
    let mut reversed = forward.clone();
    reversed.reverse();
    shuffles.push(reversed);
    let mut rotated = forward.clone();
    rotated.rotate_left(forward.len() / 3);
    shuffles.push(rotated);

    let base = batch_of(forward);
    let baseline: Vec<RunReport> = pool::with_threads(1, || {
        base.variants
            .iter()
            .map(|v| base.request_for(v).execute_on(&g).unwrap())
            .collect()
    });
    for shuffled in shuffles {
        let breq = batch_of(shuffled);
        let rep = pool::with_threads(4, || breq.execute_on(&g)).unwrap();
        for (i, got) in rep.reports.iter().enumerate() {
            let v = &breq.variants[i];
            let want = baseline
                .iter()
                .find(|b| {
                    b.spec == v.spec.canonical()
                        && b.k == v.k
                        && b.seed == v.seed
                })
                .expect("every shuffled variant exists in the baseline");
            assert_bit_identical(
                got,
                want,
                &format!("shuffled slot {i} = {}@k={}", v.spec, v.k),
            );
        }
    }
}

#[test]
fn gain_and_workload_paths_stay_bit_identical() {
    let g = graph();
    let mut breq = batch_of(vec![
        Variant::new("dfep", 4, 1).unwrap(),
        Variant::new("dfep", 4, 2).unwrap(),
        Variant::new("hdrf", 4, 1).unwrap(),
        Variant::new("dfepc", 8, 3).unwrap(),
    ]);
    breq = breq.gain_samples(2).workload(Workload::Sssp { source: 0 });
    let baseline: Vec<RunReport> = pool::with_threads(1, || {
        breq.variants
            .iter()
            .map(|v| breq.request_for(v).execute_on(&g).unwrap())
            .collect()
    });
    for threads in [2usize, 8] {
        let rep =
            pool::with_threads(threads, || breq.execute_on(&g)).unwrap();
        for (got, want) in rep.reports.iter().zip(&baseline) {
            assert_bit_identical(
                got,
                want,
                &format!("gain+workload {}@seed={}", want.spec, want.seed),
            );
            let (gw, ww) = (got.workload.as_ref(), want.workload.as_ref());
            assert_eq!(
                gw.map(|w| w.rounds),
                ww.map(|w| w.rounds),
                "workload rounds"
            );
        }
    }
}
