//! The refinement contract (DESIGN.md "Refinement pass"): for **every**
//! registry base spec, `refine:base=<spec>` (1) never worsens the total
//! replica count — the replication-factor numerator, (2) never pushes a
//! part past `max(cap, base max)` where `cap = ⌊(1+eps)·⌈m/k⌉⌋`, (3) is
//! bit-identical across 1/2/8 pool threads, and (4) leaves a valid
//! complete partition after every round. Pinned on a power-law and a
//! road-network generator at k ∈ {2, 8, 32}.

use dfep::graph::generators::GraphKind;
use dfep::graph::Graph;
use dfep::partition::refine::RefineEngine;
use dfep::partition::spec::PartitionerSpec;
use dfep::partition::view::PartitionView;
use dfep::partition::{registry, EdgePartition};
use dfep::util::pool;

const SEED: u64 = 11;
const EPS: f64 = 0.05;

fn graphs() -> Vec<(&'static str, Graph)> {
    vec![
        (
            "plc",
            GraphKind::PowerlawCluster { n: 300, m: 3, p: 0.3 }.generate(7),
        ),
        (
            "road",
            GraphKind::RoadNetwork {
                rows: 12,
                cols: 12,
                drop: 0.1,
                subdiv: 2,
                shortcuts: 8,
            }
            .generate(7),
        ),
    ]
}

/// Every registry entry as a base spec (capped rounds for the slow
/// annealer), excluding `refine` itself — self-nesting is rejected by
/// the grammar.
fn base_specs() -> Vec<String> {
    registry::all()
        .iter()
        .filter(|e| e.name != "refine")
        .map(|e| {
            if e.name == "jabeja" {
                "jabeja:rounds=10".to_string()
            } else {
                e.name.to_string()
            }
        })
        .collect()
}

/// The refine meta-spec wrapping `base` (inner commas become `+`).
fn refine_spec(base: &str) -> String {
    format!("refine:base={},rounds=4,eps={EPS}", base.replace(',', "+"))
}

fn run(g: &Graph, spec: &str, k: usize) -> EdgePartition {
    PartitionerSpec::parse(spec)
        .unwrap()
        .build()
        .partition_graph(g, k, SEED)
        .unwrap()
}

fn replica_total(g: &Graph, p: &EdgePartition) -> usize {
    PartitionView::build(g, p).replica_total()
}

fn max_size(p: &EdgePartition) -> usize {
    p.sizes().into_iter().max().unwrap_or(0)
}

/// `⌊(1+eps)·⌈m/k⌉⌋` — the engine's balance cap.
fn cap(m: usize, k: usize) -> usize {
    let ideal = m.div_ceil(k);
    let c = ((1.0 + EPS) * ideal as f64) as usize;
    c.min(m)
}

#[test]
fn refinement_never_worsens_rf_and_keeps_eps_balance() {
    for (gname, g) in graphs() {
        let m = g.edge_count();
        for base in base_specs() {
            for k in [2usize, 8, 32] {
                let before = run(&g, &base, k);
                let after = run(&g, &refine_spec(&base), k);
                let what = format!("{gname}/{base}/k={k}");
                after.validate(&g).unwrap();
                assert_eq!(after.owner.len(), m, "{what}: owner len");
                assert!(
                    replica_total(&g, &after) <= replica_total(&g, &before),
                    "{what}: refinement worsened the replica total \
                     ({} -> {})",
                    replica_total(&g, &before),
                    replica_total(&g, &after)
                );
                // refinement never *creates* imbalance: parts stay within
                // the eps cap, except where the base already exceeded it
                assert!(
                    max_size(&after) <= cap(m, k).max(max_size(&before)),
                    "{what}: max part {} > cap {} (base max {})",
                    max_size(&after),
                    cap(m, k),
                    max_size(&before)
                );
            }
        }
    }
}

#[test]
fn refined_owners_bit_identical_across_pool_widths() {
    for (gname, g) in graphs() {
        for base in base_specs() {
            for k in [2usize, 8, 32] {
                let spec = refine_spec(&base);
                let reference =
                    pool::with_threads(1, || run(&g, &spec, k));
                for threads in [2usize, 8] {
                    let got =
                        pool::with_threads(threads, || run(&g, &spec, k));
                    assert_eq!(
                        reference.owner, got.owner,
                        "{gname}/{base}/k={k}: owners differ at \
                         {threads} threads"
                    );
                    assert_eq!(
                        reference.rounds, got.rounds,
                        "{gname}/{base}/k={k}: rounds differ at \
                         {threads} threads"
                    );
                }
            }
        }
    }
}

#[test]
fn engine_rounds_keep_every_ledger_consistent() {
    let g = graphs().remove(0).1;
    let g = &g;
    let base = run(g, "random", 8);
    let mut eng = RefineEngine::new(g, &base, EPS);
    let mut last = eng.total_replicas();
    for _ in 0..16 {
        let applied = eng.round(g);
        // the owner array is a valid complete partition after *every*
        // round, and the engine's replica ledger matches a from-scratch
        // recount of it
        let part = EdgePartition {
            k: 8,
            owner: eng.owner().to_vec(),
            rounds: 0,
        };
        part.validate(g).unwrap();
        assert_eq!(
            replica_total(g, &part),
            eng.total_replicas(),
            "replica ledger drifted from the recount"
        );
        assert!(eng.total_replicas() <= last, "replica total increased");
        assert!(
            max_size(&part) <= eng.cap().max(max_size(&base)),
            "round broke the balance cap"
        );
        last = eng.total_replicas();
        if applied == 0 {
            break;
        }
    }
    // a random base leaves obvious local moves: refinement must have
    // found some (this also guards against a silently no-op engine)
    assert!(
        eng.total_replicas() < replica_total(g, &base),
        "local search found nothing to improve on a random partition"
    );
    assert!(eng.moves_applied + eng.swaps_applied > 0);
    // fixed point: once a round applies nothing, further rounds don't
    // either, and owners stay put
    let settled = eng.owner().to_vec();
    assert_eq!(eng.round(g), 0);
    assert_eq!(eng.owner(), &settled[..]);
}

#[test]
fn refine_composes_with_tuned_base_parameters() {
    let g = graphs().remove(0).1;
    let g = &g;
    // a parameterized inner spec through the full grammar: inner commas
    // written as '+', inner colon kept
    let spec = "refine:base=hdrf:lambda=1.5+group=512,rounds=2,eps=0.1";
    let refined = run(g, spec, 8);
    refined.validate(g).unwrap();
    let base = run(g, "hdrf:lambda=1.5,group=512", 8);
    assert!(replica_total(g, &refined) <= replica_total(g, &base));
}
