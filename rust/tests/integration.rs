//! Integration tests: the full pipeline across modules, including the
//! PJRT runtime path (skipped gracefully when `make artifacts` has not
//! run — CI always builds artifacts first via the Makefile).

use std::path::Path;

use dfep::cluster::cost::CostModel;
use dfep::cluster::dfep_mr::run_cluster_dfep;
use dfep::cluster::etsch_mr::{run_baseline_sssp, run_etsch_sssp};
use dfep::coordinator::runs::{resolve_graph, PartitionRequest};
use dfep::etsch::build_subgraphs;
use dfep::graph::{datasets, io, stats};
use dfep::partition::{dfep::Dfep, metrics, Partitioner};
use dfep::runtime::blocktiled::{relax_to_fixpoint, TiledSubgraph};
use dfep::runtime::{Runtime, INF32};

fn runtime() -> Option<Runtime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Runtime::open(&dir).ok()
}

#[test]
fn pipeline_dataset_to_metrics() {
    let g = resolve_graph("astroph@0.03", 1).unwrap();
    for algo in ["dfep", "dfepc", "random"] {
        let req = PartitionRequest::new(algo)
            .unwrap()
            .k(10)
            .seed(2)
            .gain_samples(2);
        let res = req.execute_on(&g).unwrap();
        res.partition.validate(&g).unwrap();
        assert!(res.metrics.largest >= 1.0);
        assert!(res.gain.unwrap() >= 0.0);
        assert!(res.timings.partition_secs >= 0.0);
    }
}

#[test]
fn dfep_beats_random_on_communication() {
    let g = resolve_graph("wordnet@0.03", 3).unwrap();
    let run = |algo: &str| {
        PartitionRequest::new(algo)
            .unwrap()
            .k(12)
            .seed(1)
            .execute_on(&g)
            .unwrap()
    };
    let d = run("dfep");
    let r = run("random");
    assert!(
        (d.metrics.messages as f64) < 0.8 * r.metrics.messages as f64,
        "DFEP messages {} should be well below random {}",
        d.metrics.messages,
        r.metrics.messages
    );
}

#[test]
fn partition_file_roundtrip() {
    let g = resolve_graph("er:n=200,m=500", 1).unwrap();
    let p = Dfep::default().partition_graph(&g, 4, 1).unwrap();
    let dir = std::env::temp_dir().join("dfep_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("partition.tsv");
    io::write_partition(&p.owner, &path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert_eq!(text.lines().count(), g.edge_count());
}

#[test]
fn cluster_jobs_agree_with_in_memory_engines() {
    let g = datasets::amazon().scaled(0.01, 5);
    let cost = CostModel::default();
    let run8 = run_cluster_dfep(&g, 8, 4, 9, &cost, 2000);
    run8.partition.validate(&g).unwrap();
    let nst = metrics::nstdev(&g, &run8.partition);
    assert!(nst < 0.8, "cluster DFEP nstdev {nst}");

    // path compression needs diameter to compress: use the road analogue
    let road = datasets::usroads().scaled(0.02, 5);
    let p = Dfep::default().partition_graph(&road, 4, 9).unwrap();
    let e = run_etsch_sssp(&road, &p, 0, 4, &cost);
    let b = run_baseline_sssp(&road, 0, 4, &cost);
    assert_eq!(e.distances, b.distances);
    assert!(
        e.rounds < b.rounds,
        "etsch {} !< baseline {}",
        e.rounds,
        b.rounds
    );
}

#[test]
fn xla_local_phase_agrees_with_subgraph_bfs() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: artifacts/ not built");
        return;
    };
    let g = resolve_graph("email-enron@0.02", 4).unwrap();
    let p = Dfep::default().partition_graph(&g, 3, 2).unwrap();
    let subs = build_subgraphs(&g, &p);
    for sub in subs.iter().filter(|s| s.vertex_count() > 0) {
        let t = TiledSubgraph::pack(sub, 1.0);
        let mut init = vec![INF32; sub.vertex_count()];
        init[0] = 0.0;
        let (labels, _) = relax_to_fixpoint(&rt, &t, &init, 4096).unwrap();
        // BFS within the subgraph
        let mut dist = vec![u32::MAX; sub.vertex_count()];
        dist[0] = 0;
        let mut q = std::collections::VecDeque::from([0u32]);
        while let Some(u) = q.pop_front() {
            for &w in sub.neighbor_vertices(u) {
                if dist[w as usize] == u32::MAX {
                    dist[w as usize] = dist[u as usize] + 1;
                    q.push_back(w);
                }
            }
        }
        for l in 0..sub.vertex_count() {
            if dist[l] == u32::MAX {
                assert!(labels[l] >= INF32 / 2.0);
            } else {
                assert_eq!(labels[l], dist[l] as f32, "part {}", sub.part);
            }
        }
    }
}

#[test]
fn xla_dfep_engine_matches_rust_engine_exactly() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: artifacts/ not built");
        return;
    };
    // same seeds, same semantics -> identical metrics (float order can in
    // principle differ, so compare the structural results)
    let g = resolve_graph("er:n=400,m=1200", 6).unwrap();
    let px = dfep::runtime::xla_engine::XlaDfep::default()
        .partition(&rt, &g, 6, 11)
        .unwrap();
    let pr = Dfep::default().partition_graph(&g, 6, 11).unwrap();
    px.validate(&g).unwrap();
    assert_eq!(px.rounds, pr.rounds, "round counts diverged");
    assert_eq!(
        metrics::messages(&g, &px),
        metrics::messages(&g, &pr),
        "frontier structure diverged"
    );
    assert_eq!(px.owner, pr.owner, "ownership diverged");
}

#[test]
fn dataset_stats_match_paper_character_at_small_scale() {
    // small-world datasets keep small diameter + real clustering even at
    // 3% scale; the road network keeps its huge diameter
    for (name, max_d, min_cc) in
        [("astroph", 14, 0.05), ("wordnet", 16, 0.03)]
    {
        let g = datasets::by_name(name).unwrap().scaled(0.03, 7);
        let s = stats::graph_stats(&g, 1);
        assert!(s.diameter <= max_d, "{name}: D {}", s.diameter);
        assert!(s.clustering >= min_cc, "{name}: CC {}", s.clustering);
    }
    let road = datasets::usroads().scaled(0.03, 7);
    let s = stats::graph_stats(&road, 1);
    assert!(s.diameter > 60, "usroads: D {}", s.diameter);
}
