//! Chaos soak: the deterministic fault plane (`util::fault`) against
//! the real cluster runtime and the real serve stack.
//!
//! The contract under test, from DESIGN.md "Fault plane": with a
//! seeded plan injecting drops, delays, corruption, short reads and
//! torn writes at the wire and disk chokepoints, a run must end in one
//! of exactly two states — owners *bit-identical* to the fault-free
//! single-process facade, or a typed `ErrorKind::Transport` error.
//! Never a wrong answer, never a hang, never a panic. And because
//! every arm's decision stream derives from the plan seed, the same
//! configuration must replay the same fault sequence bit-for-bit.
//!
//! Like `tests/cluster.rs`, all cluster runs use `in_process: true`.

use dfep::cluster::runtime::{run_cluster, ClusterConfig};
use dfep::coordinator::runs::PartitionRequest;
use dfep::coordinator::serve::{ServeClient, ServeConfig, Server};
use dfep::util::error::ErrorKind;
use dfep::util::fault::{FaultPlan, RetryPolicy};

const DATASET: &str = "plc:n=400,m=4,p=0.3";
const K: usize = 8;
const SEED: u64 = 3;
const GRAPH_SEED: u64 = 7;

/// The fault-free single-process reference owners.
fn facade_owner() -> Vec<u32> {
    PartitionRequest::new("dfep")
        .unwrap()
        .dataset(DATASET)
        .k(K)
        .seed(SEED)
        .graph_seed(GRAPH_SEED)
        .execute()
        .unwrap()
        .partition
        .owner
}

/// A cluster config under a given plan: frequent checkpoints (cheap
/// rollback floors) and a generous recovery budget, so the soak
/// usually completes — and when the dice exhaust the budget anyway,
/// the typed-error arm of the contract is what gets exercised.
fn chaos_cfg(workers: usize, plan: FaultPlan) -> ClusterConfig {
    ClusterConfig {
        workers,
        k: K,
        seed: SEED,
        spec: "dfep".into(),
        dataset: DATASET.into(),
        graph_seed: GRAPH_SEED,
        checkpoint_every: 2,
        fault: Some(plan),
        worker_timeout_ms: 5_000,
        in_process: true,
        max_recoveries: 64,
        ..ClusterConfig::default()
    }
}

/// The soak plan: every wire knob on at rates that fire dozens of
/// times over a run without (usually) exhausting the budget.
fn soak_plan() -> FaultPlan {
    FaultPlan::parse(
        "fault:seed=42,drop=0.01,delay_ms=0..2,corrupt=0.005,\
         short_read=0.005,torn_write=0.005",
    )
    .unwrap()
}

#[test]
fn cluster_chaos_is_exact_or_typed_at_any_worker_count() {
    let reference = facade_owner();
    for workers in [1usize, 2, 4] {
        let cfg = chaos_cfg(workers, soak_plan());
        match run_cluster(&cfg) {
            Ok(rep) => {
                assert_eq!(
                    rep.partition.owner, reference,
                    "{workers}-worker chaos owners diverge from the facade"
                );
            }
            Err(e) => {
                assert_eq!(e.kind(), ErrorKind::Transport, "{e}");
            }
        }
    }
}

#[test]
fn cluster_chaos_replays_bit_identically_from_its_seed() {
    let cfg = chaos_cfg(3, soak_plan());
    let a = run_cluster(&cfg);
    let b = run_cluster(&cfg);
    match (a, b) {
        (Ok(a), Ok(b)) => {
            assert_eq!(
                a.partition.owner, b.partition.owner,
                "replayed owners diverge"
            );
            // the whole fault sequence replays: same tallies, same
            // number of recoveries, same recovery traffic
            assert_eq!(a.faults, b.faults, "fault tallies diverge");
            assert_eq!(a.recoveries, b.recoveries);
            assert_eq!(a.measured.recovery, b.measured.recovery);
            assert!(
                a.faults.total() > 0,
                "the soak plan never fired — rates too low to test anything"
            );
            assert_eq!(a.partition.owner, facade_owner());
        }
        (Err(a), Err(b)) => {
            assert_eq!(a.kind(), ErrorKind::Transport, "{a}");
            assert_eq!(b.kind(), ErrorKind::Transport, "{b}");
        }
        (a, b) => panic!(
            "replay diverged: first run ok={}, second run ok={}",
            a.is_ok(),
            b.is_ok()
        ),
    }
}

#[test]
fn corrupt_on_disk_checkpoint_falls_back_to_previous_intact_round() {
    let reference = facade_owner();
    let dir = std::env::temp_dir().join("dfep_chaos_ckpt_fallback");
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = ClusterConfig {
        workers: 3,
        k: K,
        seed: SEED,
        spec: "dfep".into(),
        dataset: DATASET.into(),
        graph_seed: GRAPH_SEED,
        checkpoint_every: 2,
        checkpoint_dir: Some(dir.clone()),
        in_process: true,
        ..ClusterConfig::default()
    };
    let rep = run_cluster(&cfg).unwrap();
    assert_eq!(rep.partition.owner, reference);
    // enumerate the persisted rounds off the meta files
    let mut rounds: Vec<u64> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| {
            let name = e.ok()?.file_name().into_string().ok()?;
            name.strip_prefix("ckpt_r")?
                .strip_suffix("_meta.bin")?
                .parse()
                .ok()
        })
        .collect();
    rounds.sort_unstable();
    assert!(
        rounds.len() >= 2,
        "need two persisted rounds to test fallback, got {rounds:?}"
    );
    let newest = *rounds.last().unwrap();
    let fallback = rounds[rounds.len() - 2];
    // bit-rot the newest round: flip one payload byte in a rank blob
    let victim = dir.join(format!("ckpt_r{newest}_w1.bin"));
    let mut bytes = std::fs::read(&victim).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(&victim, &bytes).unwrap();
    // resume: the damaged round must be skipped (checksum, not trust),
    // the previous intact one restored, and the answer unchanged
    let cfg2 = ClusterConfig { resume: true, ..cfg.clone() };
    let rep2 = run_cluster(&cfg2).unwrap();
    assert_eq!(rep2.skipped_checkpoints, 1, "the flipped byte went unnoticed");
    assert_eq!(rep2.resumed_round, Some(fallback));
    assert_eq!(rep2.partition.owner, reference);
    // and an undamaged resume picks the newest round of the rerun
    let rep3 = run_cluster(&cfg2).unwrap();
    assert_eq!(rep3.skipped_checkpoints, 0);
    assert!(rep3.resumed_round.is_some());
    assert_eq!(rep3.partition.owner, reference);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Read `/stats` through the chaos, retrying past injected faults.
fn stats_json(c: &mut ServeClient) -> dfep::util::json::Json {
    for _ in 0..50 {
        if let Ok((200, body)) = c.get("/stats") {
            return dfep::util::json::parse(&body).unwrap();
        }
    }
    panic!("/stats unreachable through 50 attempts");
}

fn stat(j: &dfep::util::json::Json, key: &str) -> f64 {
    j.get(key)
        .unwrap_or_else(|| panic!("no '{key}' in /stats"))
        .as_f64()
        .unwrap()
}

#[test]
fn serve_chaos_sequential_client_retries_to_exact_answers() {
    // hot rates: roughly half of all request/response operations fault,
    // so the client's backoff loop is doing real work on every run
    let plan = FaultPlan::parse(
        "fault:seed=9,drop=0.15,corrupt=0.1,short_read=0.1,torn_write=0.1",
    )
    .unwrap();
    let server = Server::spawn(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        fault: Some(plan),
        ..Default::default()
    })
    .unwrap();
    let req = PartitionRequest::new("dfep")
        .unwrap()
        .dataset("er:n=300,m=900")
        .k(6)
        .seed(3);
    let direct = req.execute().unwrap();
    let mut c = ServeClient::connect(server.addr())
        .with_retry(RetryPolicy { attempts: 8, base_ms: 1, max_ms: 4 });
    let mut ok = 0usize;
    for _ in 0..30 {
        match c.partition(&req, true) {
            Ok(rep) => {
                ok += 1;
                assert_eq!(
                    rep.partition.owner, direct.partition.owner,
                    "a served chaos answer diverged from direct execution"
                );
            }
            // a request may exhaust its retry budget, but only ever
            // with the typed retryable kind — never a wrong answer
            Err(e) => assert_eq!(e.kind(), ErrorKind::Transport, "{e}"),
        }
    }
    assert!(ok > 0, "every chaos request failed");
    assert!(c.retries() > 0, "chaos never forced a client retry");
    let j = stats_json(&mut c);
    assert_eq!(stat(&j, "fault_active"), 1.0);
    let injected = stat(&j, "fault_drops")
        + stat(&j, "fault_corruptions")
        + stat(&j, "fault_short_reads")
        + stat(&j, "fault_torn_writes");
    assert!(injected > 0.0, "the server tallied no injections");
    // every injected request corruption trips the digest check
    assert_eq!(stat(&j, "transport_corrupt"), stat(&j, "fault_corruptions"));
}

#[test]
fn serve_chaos_concurrent_soak_never_serves_a_wrong_answer() {
    let plan = FaultPlan::parse(
        "fault:seed=1234,drop=0.08,corrupt=0.05,short_read=0.05,\
         torn_write=0.05",
    )
    .unwrap();
    let server = Server::spawn(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 4,
        fault: Some(plan),
        ..Default::default()
    })
    .unwrap();
    let addr = server.addr();
    let req = PartitionRequest::new("dfep")
        .unwrap()
        .dataset("er:n=400,m=1200")
        .k(8)
        .seed(11);
    let direct = req.execute().unwrap();
    let successes: usize = std::thread::scope(|s| {
        let mut threads = Vec::new();
        for _ in 0..6usize {
            let req = &req;
            let direct = &direct;
            threads.push(s.spawn(move || {
                let mut c = ServeClient::connect(addr).with_retry(
                    RetryPolicy { attempts: 6, base_ms: 1, max_ms: 4 },
                );
                let mut ok = 0usize;
                for _ in 0..8 {
                    match c.partition(req, true) {
                        Ok(rep) => {
                            ok += 1;
                            assert_eq!(
                                rep.partition.owner,
                                direct.partition.owner
                            );
                        }
                        Err(e) => assert_eq!(
                            e.kind(),
                            ErrorKind::Transport,
                            "{e}"
                        ),
                    }
                }
                ok
            }));
        }
        threads.into_iter().map(|t| t.join().unwrap()).sum()
    });
    assert!(successes > 0, "no concurrent chaos request ever succeeded");
    let mut c = ServeClient::connect(addr);
    let j = stats_json(&mut c);
    assert!(
        stat(&j, "fault_drops")
            + stat(&j, "fault_corruptions")
            + stat(&j, "fault_short_reads")
            + stat(&j, "fault_torn_writes")
            > 0.0,
        "the server tallied no injections"
    );
    // single-flight held through the chaos: identical requests computed
    // at most a handful of times (cache misses only on raced starts)
    assert!(stat(&j, "computations") >= 1.0);
}
