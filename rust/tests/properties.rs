//! Property-based invariants over the public API (see DESIGN.md
//! "Invariants"), driven by the crate's own mini prop-test harness —
//! every failure message carries the deterministic case seed.

use dfep::etsch::{
    cc::ConnectedComponents, kcore::KCore, labelprop::LabelPropagation,
    mis, mis::LubyMis, pagerank::PageRank, sssp, sssp::Sssp, Etsch,
};
use dfep::graph::stats;
use dfep::partition::spec::{self, PartitionerSpec};
use dfep::partition::view::PartitionView;
use dfep::partition::{
    baselines::RandomEdge, dfep::Dfep, dfep::DfepState, metrics, registry,
    Partitioner,
};
use dfep::testing::prop::{forall, Gen};
use dfep::util::rng::Rng;

/// Every registered partitioner with default parameters — the registry is
/// the one source of truth, so a newly registered algorithm is property-
/// tested automatically. JaBeJa's swap rounds are capped through its own
/// spec grammar to keep the suite fast.
fn partitioners() -> Vec<Box<dyn Partitioner>> {
    registry::all()
        .iter()
        .map(|e| match e.name {
            "jabeja" => PartitionerSpec::parse("jabeja:rounds=15")
                .unwrap()
                .build(),
            _ => spec::default_spec(e).build(),
        })
        .collect()
}

// Every test below threads *explicit* `u64` seeds: each case draws its
// named seeds up front (graph construction happens inside `Gen`, which is
// itself a deterministic function of the case seed), so no assertion
// depends on ambient draw order or on container iteration order. The
// `every_partitioner_is_deterministic_per_seed` property pins this.

#[test]
fn every_partitioner_yields_a_disjoint_cover() {
    forall(12, |g: &mut Gen| {
        let graph = g.any_graph(12, 120);
        let k = g.int(1, 9);
        let part_seed: u64 = g.rng.next_u64();
        for p in partitioners() {
            let part = p.partition_graph(&graph, k, part_seed).unwrap();
            // complete cover with valid owners is exactly validate()
            part.validate(&graph).unwrap_or_else(|e| {
                panic!("{}: {e}", p.name());
            });
            // sizes sum to |E|
            assert_eq!(
                part.sizes().iter().sum::<usize>(),
                graph.edge_count(),
                "{} loses edges",
                p.name()
            );
        }
    });
}

#[test]
fn every_partitioner_is_deterministic_per_seed() {
    // same explicit seed => identical ownership, twice over — guards
    // against implicit randomness (thread scheduling, hash-map iteration
    // order) leaking into any partitioner
    forall(6, |g: &mut Gen| {
        let graph = g.any_graph(12, 100);
        let k = g.int(2, 6);
        let part_seed: u64 = g.rng.next_u64();
        for p in partitioners() {
            let a = p.partition_graph(&graph, k, part_seed).unwrap();
            let b = p.partition_graph(&graph, k, part_seed).unwrap();
            assert_eq!(a.owner, b.owner, "{} not deterministic", p.name());
            assert_eq!(a.rounds, b.rounds, "{} rounds differ", p.name());
        }
    });
}

#[test]
fn vertex_sets_are_exactly_edge_endpoints() {
    forall(10, |g: &mut Gen| {
        let graph = g.any_graph(12, 100);
        let k = g.int(2, 6);
        let part_seed: u64 = g.rng.next_u64();
        let part = Dfep::default().partition_graph(&graph, k, part_seed).unwrap();
        let vsets = part.vertex_sets(&graph);
        let esets = part.edge_sets();
        for (vs, es) in vsets.iter().zip(esets.iter()) {
            let mut expect: Vec<u32> = es
                .iter()
                .flat_map(|&e| {
                    let (u, v) = graph.endpoints(e);
                    [u, v]
                })
                .collect();
            expect.sort_unstable();
            expect.dedup();
            let mut got = vs.clone();
            got.sort_unstable();
            assert_eq!(got, expect);
        }
    });
}

#[test]
fn partition_view_agrees_with_slow_derivations() {
    // PartitionView derives everything in one pool-parallel build; the
    // slow per-consumer derivations (edge_sets / vertex_sets and counts
    // recomputed from them) survive exactly as the oracle here
    forall(8, |g: &mut Gen| {
        let graph = g.any_graph(12, 100);
        let k = g.int(1, 6);
        let part_seed: u64 = g.rng.next_u64();
        for p in partitioners() {
            let part = p.partition_graph(&graph, k, part_seed).unwrap();
            let view = PartitionView::build(&graph, &part);
            let name = p.name();
            // per-part edge CSR == slow edge_sets (ascending in both)
            let esets = part.edge_sets();
            for pi in 0..part.k {
                assert_eq!(
                    view.edges_of(pi),
                    &esets[pi][..],
                    "{name}: part {pi} edges"
                );
            }
            assert_eq!(view.sizes(), &part.sizes()[..], "{name}: sizes");
            // per-part dense vertex ids == slow vertex_sets, including
            // the first-appearance order
            let vsets = part.vertex_sets(&graph);
            for (pi, sub) in view.subgraphs().iter().enumerate() {
                assert_eq!(
                    sub.global, vsets[pi],
                    "{name}: part {pi} vertex order"
                );
                for (l, &gv) in sub.global.iter().enumerate() {
                    assert_eq!(
                        sub.frontier[l],
                        view.multiplicity[gv as usize] >= 2,
                        "{name}: frontier flag of {gv}"
                    );
                }
            }
            // multiplicity: stamp-pass == view == recount of vertex_sets
            let mut slow_mult = vec![0u32; graph.vertex_count()];
            for vs in &vsets {
                for &v in vs {
                    slow_mult[v as usize] += 1;
                }
            }
            assert_eq!(
                part.vertex_multiplicity(&graph),
                slow_mult,
                "{name}: vertex_multiplicity"
            );
            assert_eq!(view.multiplicity, slow_mult, "{name}: view mult");
            // replica table inverts the subgraph global maps
            for v in 0..graph.vertex_count() as u32 {
                let reps = view.replicas_of(v);
                assert_eq!(
                    reps.len(),
                    slow_mult[v as usize] as usize,
                    "{name}: replica count of {v}"
                );
                for &(pi, l) in reps {
                    assert_eq!(
                        view.subgraphs()[pi as usize].global[l as usize],
                        v,
                        "{name}: replica slot of {v}"
                    );
                }
            }
            // MESSAGES
            let expect: usize = slow_mult
                .iter()
                .filter(|&&c| c >= 2)
                .map(|&c| c as usize)
                .sum();
            assert_eq!(view.messages(), expect, "{name}: messages");
            assert_eq!(
                metrics::messages(&graph, &part),
                expect,
                "{name}: metrics::messages"
            );
        }
    });
}

#[test]
fn dirty_aggregation_matches_dense_reference() {
    // change-driven aggregation must be observationally identical to the
    // dense re-aggregate-everything reference: same final states, same
    // round counts, same message counts — across algorithm families
    // (min-reconciled, sum-reconciled, randomized)
    forall(6, |g: &mut Gen| {
        let graph = g.any_graph(12, 100);
        let k = g.int(1, 6);
        let part_seed: u64 = g.rng.next_u64();
        let source = g.int(0, graph.vertex_count() - 1) as u32;
        let alg_seed: u64 = g.rng.next_u64();
        for p in partitioners() {
            let part = p.partition_graph(&graph, k, part_seed).unwrap();
            let view = PartitionView::build(&graph, &part);
            let name = p.name();

            macro_rules! check {
                ($label:expr, $mk:expr) => {{
                    let (a, ra, sa) = {
                        let mut e = Etsch::from_view(&graph, &view);
                        let out = e.run(&mut $mk);
                        (out, e.rounds_executed(), e.stats().clone())
                    };
                    let (b, rb, sb) = {
                        let mut e = Etsch::from_view(&graph, &view);
                        let out = e.run_dense(&mut $mk);
                        (out, e.rounds_executed(), e.stats().clone())
                    };
                    assert_eq!(a, b, "{name}/{}: states", $label);
                    assert_eq!(ra, rb, "{name}/{}: rounds", $label);
                    assert_eq!(
                        sa.messages_exchanged, sb.messages_exchanged,
                        "{name}/{}: exchanged",
                        $label
                    );
                    assert_eq!(
                        sa.messages_ceiling, sb.messages_ceiling,
                        "{name}/{}: ceiling",
                        $label
                    );
                }};
            }

            check!("sssp", Sssp::new(source));
            check!("cc", ConnectedComponents::new(alg_seed));
            check!("pagerank", PageRank::new(&graph, 8));
            check!("mis", LubyMis::new(alg_seed));
            check!("kcore", KCore::new(3));
            check!("labelprop", LabelPropagation::default());
        }
    });
}

#[test]
fn dfep_valid_connected_and_conserving_at_k_4_and_16() {
    // re-check the radix/stamp/ledger round engine on both generator
    // families the paper's figures use, at a small and a large k:
    // validity, connectedness (a construction guarantee of plain DFEP on
    // connected inputs), and per-round money conservation
    use dfep::graph::generators::GraphKind;
    let graphs = [
        (
            "powerlaw",
            GraphKind::PowerlawCluster { n: 1_500, m: 5, p: 0.3 }
                .generate(21),
        ),
        (
            "road",
            GraphKind::RoadNetwork {
                rows: 14,
                cols: 14,
                drop: 0.0,
                subdiv: 2,
                shortcuts: 0,
            }
            .generate(22),
        ),
    ];
    for (name, graph) in &graphs {
        for k in [4usize, 16] {
            let part =
                Dfep::default().partition_graph(graph, k, 7).unwrap();
            part.validate(graph).unwrap();
            assert_eq!(
                part.sizes().iter().sum::<usize>(),
                graph.edge_count(),
                "{name} k={k}: sizes must tile the edge set"
            );
            let disc = metrics::disconnected_fraction(graph, &part);
            assert_eq!(
                disc, 0.0,
                "{name} k={k}: plain DFEP must stay connected"
            );
            // conservation across raw engine rounds: money + edges
            // bought is invariant under funding_round (the coordinator
            // is the only injector)
            let mut rng = Rng::new(9);
            let initial = (graph.edge_count() as f64 / k as f64).max(1.0);
            let mut st = DfepState::new(graph, k, initial, &mut rng);
            for round in 0..10 {
                let before =
                    st.total_money() + st.sizes.iter().sum::<usize>() as f64;
                st.funding_round(graph, None, None);
                let after =
                    st.total_money() + st.sizes.iter().sum::<usize>() as f64;
                assert!(
                    (before - after).abs() < 1e-6 * before.max(1.0),
                    "{name} k={k} round {round}: money leaked \
                     {before} -> {after}"
                );
                st.coordinator_step(10.0);
                if st.free_edges == 0 {
                    break;
                }
            }
        }
    }
}

#[test]
fn dfep_partitions_connected_on_connected_graphs() {
    forall(10, |g: &mut Gen| {
        let graph = g.graph(20, 150); // connected by construction
        let k = g.int(2, 8);
        let part_seed: u64 = g.rng.next_u64();
        let part = Dfep::default().partition_graph(&graph, k, part_seed).unwrap();
        let disc = metrics::disconnected_fraction(&graph, &part);
        assert_eq!(
            disc, 0.0,
            "DFEP produced disconnected partitions (k={k})"
        );
    });
}

#[test]
fn messages_metric_counts_replicas() {
    forall(10, |g: &mut Gen| {
        let graph = g.any_graph(12, 80);
        let k = g.int(2, 5);
        let part_seed: u64 = g.rng.next_u64();
        let part = RandomEdge.partition_graph(&graph, k, part_seed).unwrap();
        // independent recomputation from vertex_sets
        let vsets = part.vertex_sets(&graph);
        let mut count = vec![0usize; graph.vertex_count()];
        for vs in &vsets {
            for &v in vs {
                count[v as usize] += 1;
            }
        }
        let expect: usize =
            count.iter().filter(|&&c| c >= 2).sum();
        assert_eq!(metrics::messages(&graph, &part), expect);
    });
}

#[test]
fn etsch_sssp_equals_bfs_under_any_partitioning() {
    forall(10, |g: &mut Gen| {
        let graph = g.any_graph(12, 100);
        let k = g.int(1, 6);
        let part_seed: u64 = g.rng.next_u64();
        let source = g.int(0, graph.vertex_count() - 1) as u32;
        for p in partitioners() {
            let part = p.partition_graph(&graph, k, part_seed).unwrap();
            let mut engine = Etsch::new(&graph, &part);
            let got = engine.run(&mut Sssp::new(source));
            let want = stats::bfs_distances(&graph, source);
            for v in 0..graph.vertex_count() {
                let w = if want[v] == u32::MAX {
                    sssp::UNREACHED
                } else {
                    want[v]
                };
                assert_eq!(
                    got[v], w,
                    "{}: vertex {v} (source {source})",
                    p.name()
                );
            }
        }
    });
}

#[test]
fn etsch_cc_equals_union_find_components() {
    forall(10, |g: &mut Gen| {
        let graph = g.any_graph(12, 100);
        let k = g.int(1, 6);
        let part_seed: u64 = g.rng.next_u64();
        let label_seed: u64 = g.rng.next_u64();
        let part = RandomEdge.partition_graph(&graph, k, part_seed).unwrap();
        let mut engine = Etsch::new(&graph, &part);
        let labels =
            engine.run(&mut ConnectedComponents::new(label_seed));
        let (want, _) = stats::components(&graph);
        for u in 0..graph.vertex_count() {
            for v in (u + 1)..graph.vertex_count() {
                if graph.degree(u as u32) == 0 || graph.degree(v as u32) == 0
                {
                    continue;
                }
                assert_eq!(
                    labels[u] == labels[v],
                    want[u] == want[v],
                    "vertices {u},{v}"
                );
            }
        }
    });
}

#[test]
fn luby_mis_always_valid() {
    forall(8, |g: &mut Gen| {
        let graph = g.graph(15, 90);
        let k = g.int(1, 5);
        let part_seed: u64 = g.rng.next_u64();
        let luby_seed: u64 = g.rng.next_u64();
        let part = Dfep::default().partition_graph(&graph, k, part_seed).unwrap();
        let mut engine = Etsch::new(&graph, &part);
        let states = engine.run(&mut LubyMis::new(luby_seed));
        let in_set: Vec<bool> = states
            .iter()
            .map(|s| s.status == mis::Status::InSet)
            .collect();
        mis::validate_mis(&graph, &in_set).unwrap();
    });
}

#[test]
fn rounds_and_gain_are_sane() {
    forall(8, |g: &mut Gen| {
        let graph = g.graph(20, 120);
        let k = g.int(2, 6);
        let part_seed: u64 = g.rng.next_u64();
        let gain_seed: u64 = g.rng.next_u64();
        let part = Dfep::default().partition_graph(&graph, k, part_seed).unwrap();
        assert!(part.rounds > 0);
        let gain = dfep::etsch::gain::average_gain(
            &graph,
            &part,
            2,
            gain_seed,
        );
        assert!((0.0..=1.0).contains(&gain), "gain {gain}");
    });
}

#[test]
fn rewiring_preserves_vertexish_size_and_lowers_diameter_in_trend() {
    forall(6, |g: &mut Gen| {
        use dfep::graph::generators::GraphKind;
        use dfep::graph::rewire;
        let side = g.int(8, 13);
        let road_seed: u64 = g.rng.next_u64();
        let rewire_seed: u64 = g.rng.next_u64();
        let graph = GraphKind::RoadNetwork {
            rows: side,
            cols: side,
            drop: 0.15,
            subdiv: 3,
            shortcuts: 0,
        }
        .generate(road_seed);
        let rewired =
            rewire::rewire_fraction(&graph, 0.3, rewire_seed);
        assert!(
            rewired.edge_count() as f64
                >= 0.85 * graph.edge_count() as f64
        );
        let d0 = stats::diameter_estimate(&graph, 3, 1);
        let d1 = stats::diameter_estimate(&rewired, 3, 1);
        assert!(d1 <= d0, "rewiring increased diameter {d0} -> {d1}");
    });
}

#[test]
fn cluster_cost_monotone_in_nodes() {
    use dfep::cluster::cost::{CostModel, RoundWork};
    forall(10, |g: &mut Gen| {
        let m = CostModel::default();
        let w = RoundWork {
            map_records: g.float(1e3, 1e7),
            shuffle_bytes: g.float(1e3, 1e8),
            reduce_records: g.float(1e3, 1e7),
            cpu_edge_ops: 0.0,
        };
        let mut prev = f64::INFINITY;
        for nodes in [1usize, 2, 4, 8, 16, 32] {
            let t = m.round_time(nodes, w);
            assert!(t > 0.0);
            assert!(
                t <= prev * 1.001,
                "cost not monotone at {nodes} nodes"
            );
            prev = t;
        }
    });
}
