//! Allocation budget for batched variant execution: after a warm-up
//! variant has grown every DFEP buffer to its high-water capacity,
//! recycling the state for the next variant (`DfepState::reset`, the
//! exact path batch lanes take through the parked-state pool) must
//! perform **zero** heap allocations — reset through every funding
//! round.
//!
//! Same counting-`#[global_allocator]` pattern as `tests/alloc_budget.rs`
//! (and the same single-test-per-binary rule, so no concurrent test
//! thread perturbs the counter). The engine runs on a single-thread pool
//! so the count reflects the engine, not pool transport.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use dfep::graph::generators::GraphKind;
use dfep::partition::dfep::{reseed_on_free_edge, DfepState};
use dfep::util::pool;
use dfep::util::rng::Rng;

struct CountingAlloc;

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc(layout) }
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc_zeroed(layout) }
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.realloc(ptr, layout, new_size) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }
}

#[cfg(not(miri))]
#[global_allocator]
static GLOBAL_COUNTER: CountingAlloc = CountingAlloc;

fn alloc_count() -> u64 {
    ALLOCATIONS.load(Ordering::Relaxed)
}

/// Drive one full DFEP run on a recycled-or-fresh state; returns when
/// the partition converged (panics if it stalls past the round cap).
fn run_to_completion(g: &dfep::graph::Graph, st: &mut DfepState, rng: &mut Rng) {
    let mut stall = 0usize;
    while st.free_edges > 0 && st.rounds < 1_000 {
        let before_free = st.free_edges;
        st.funding_round(g, None, None);
        st.coordinator_step(10.0);
        if st.free_edges == before_free {
            stall += 1;
            if stall >= 3 {
                reseed_on_free_edge(g, st, rng);
                stall = 0;
            }
        } else {
            stall = 0;
        }
    }
    assert_eq!(st.free_edges, 0, "engine did not converge");
}

#[test]
#[cfg_attr(miri, ignore = "the counting allocator is disabled under miri")]
fn recycled_variant_allocates_zero_after_warmup() {
    pool::with_threads(1, || {
        let g = GraphKind::ErdosRenyi { n: 2_000, m: 12_000 }.generate(42);
        let k = 8usize;
        let initial = (g.edge_count() as f64 / k as f64).max(1.0);
        // warm-up variant: grows every buffer to its high-water capacity
        let mut rng = Rng::new(1);
        let mut st = DfepState::new(&g, k, initial, &mut rng);
        run_to_completion(&g, &mut st, &mut rng);
        // identical next variant: the trajectory revisits exactly the
        // warm-up's buffer sizes, so reset + every round must stay
        // within retained capacity — strictly zero allocations
        let mut rng2 = Rng::new(1);
        let a0 = alloc_count();
        st.reset(&g, k, initial, &mut rng2);
        run_to_completion(&g, &mut st, &mut rng2);
        let same_seed_delta = alloc_count() - a0;
        assert_eq!(
            same_seed_delta, 0,
            "recycling a parked state for an identical variant allocated"
        );
        // different-seed variant: early rounds may grow a buffer past
        // the warm-up high-water, but the steady-state tail must be
        // allocation-free, exactly like a fresh state's tail
        let mut rng3 = Rng::new(99);
        st.reset(&g, k, initial, &mut rng3);
        let mut deltas: Vec<u64> = Vec::with_capacity(1_100);
        let mut stall = 0usize;
        while st.free_edges > 0 && st.rounds < 1_000 {
            let before_free = st.free_edges;
            let a0 = alloc_count();
            st.funding_round(&g, None, None);
            st.coordinator_step(10.0);
            if st.free_edges == before_free {
                stall += 1;
                if stall >= 3 {
                    reseed_on_free_edge(&g, &mut st, &mut rng3);
                    stall = 0;
                }
            } else {
                stall = 0;
            }
            deltas.push(alloc_count() - a0);
        }
        assert_eq!(st.free_edges, 0, "engine did not converge");
        let tail = (deltas.len() / 4).max(5).min(deltas.len());
        let suffix = &deltas[deltas.len() - tail..];
        assert!(
            suffix.iter().all(|&d| d == 0),
            "steady-state rounds on a recycled state still allocate: last \
             {tail} of {} round deltas = {suffix:?}",
            deltas.len()
        );
    });
}
