//! Allocation budget for the DFEP round engine: after warm-up, a funding
//! round + coordinator step must perform **zero** heap allocations — the
//! persistent `RoundScratch` and flat `MoneyLedger` are the whole point.
//!
//! A counting `#[global_allocator]` (cfg-gated off under miri, which
//! supplies its own allocator machinery) wraps the system allocator and
//! counts every `alloc`/`realloc`. This file is its own test binary and
//! contains exactly one test, so no concurrent test thread can perturb
//! the counter mid-measurement. The engine is driven on a single-thread
//! pool: with one worker the pool runs shards inline, so the count
//! reflects the engine's own buffers, not the pool's channel transport.
//!
//! The assertion: once the run passes its mid-run peak (holder/frontier
//! buffers at their high-water capacity), every remaining round must
//! allocate nothing — the trailing quarter of the rounds (at least 5)
//! must all have a zero allocation delta. A regression that re-introduces
//! a per-round `Vec` shows up in every round and trips this immediately.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use dfep::graph::generators::GraphKind;
use dfep::partition::dfep::{reseed_on_free_edge, DfepState};
use dfep::util::pool;
use dfep::util::rng::Rng;

/// Counts allocation events (`alloc` + growing `realloc`); frees are not
/// counted — the budget is about acquiring memory in steady state.
struct CountingAlloc;

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc(layout) }
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc_zeroed(layout) }
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.realloc(ptr, layout, new_size) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }
}

#[cfg(not(miri))]
#[global_allocator]
static GLOBAL_COUNTER: CountingAlloc = CountingAlloc;

fn alloc_count() -> u64 {
    ALLOCATIONS.load(Ordering::Relaxed)
}

#[test]
#[cfg_attr(miri, ignore = "the counting allocator is disabled under miri")]
fn dfep_round_steady_state_allocates_zero() {
    pool::with_threads(1, || {
        // ER degrees are concentrated, so per-shard work in the end-game
        // is strictly below the mid-run peak and capacities are settled
        // long before the measured tail
        let g = GraphKind::ErdosRenyi { n: 2_000, m: 12_000 }.generate(42);
        let k = 8usize;
        let initial = (g.edge_count() as f64 / k as f64).max(1.0);
        let mut rng = Rng::new(1);
        let mut st = DfepState::new(&g, k, initial, &mut rng);
        // pre-size the delta log so recording never allocates mid-loop
        let mut deltas: Vec<u64> = Vec::with_capacity(1_100);
        let mut stall = 0usize;
        while st.free_edges > 0 && st.rounds < 1_000 {
            let before_free = st.free_edges;
            let a0 = alloc_count();
            st.funding_round(&g, None, None);
            st.coordinator_step(10.0);
            if st.free_edges == before_free {
                stall += 1;
                if stall >= 3 {
                    // the stall walk is part of the budget too
                    reseed_on_free_edge(&g, &mut st, &mut rng);
                    stall = 0;
                }
            } else {
                stall = 0;
            }
            deltas.push(alloc_count() - a0);
        }
        assert_eq!(
            st.free_edges, 0,
            "engine did not converge within 1000 rounds (rounds={}, \
             sizes={:?})",
            st.rounds, st.sizes
        );
        let tail = (deltas.len() / 4).max(5).min(deltas.len());
        let suffix = &deltas[deltas.len() - tail..];
        assert!(
            suffix.iter().all(|&d| d == 0),
            "steady-state rounds still allocate: last {tail} of {} round \
             deltas = {suffix:?}",
            deltas.len()
        );
        // sanity: warm-up genuinely allocated (the counter works)
        assert!(
            deltas.first().copied().unwrap_or(0) > 0,
            "first round allocated nothing — counting allocator inactive?"
        );
    });
}
