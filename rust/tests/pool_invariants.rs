//! Pool-determinism coverage: graph IO round-trip fidelity and partition
//! invariants under the shared thread pool — every edge owned exactly
//! once, and balance / communication metrics (in fact the whole ownership
//! vector) bit-stable across 1, 2 and 8 pool threads. Also pins the
//! parallel `PartitionView` build and ETSCH's change-driven aggregation
//! to the same contract.

use dfep::etsch::{sssp::Sssp, Etsch};
use dfep::graph::{generators::GraphKind, io};
use dfep::partition::view::PartitionView;
use dfep::partition::{
    dfep::Dfep, dfep::DfepState, dfepc::Dfepc, metrics, Partitioner,
};
use dfep::util::pool;
use dfep::util::rng::Rng;

#[test]
fn graph_io_roundtrip_reproduces_identical_csr() {
    let g = GraphKind::PowerlawCluster { n: 600, m: 4, p: 0.3 }.generate(11);
    let dir = std::env::temp_dir().join("dfep_pool_invariants");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("roundtrip.txt");
    io::write_edge_list(&g, &path).unwrap();
    let g2 = io::read_edge_list(&path, false).unwrap();
    assert_eq!(g.vertex_count(), g2.vertex_count());
    assert_eq!(g.edge_count(), g2.edge_count());
    // identical canonical edge list => identical edge ids
    assert_eq!(g.edges(), g2.edges());
    // identical CSR adjacency (neighbors + edge ids, in order)
    for v in 0..g.vertex_count() as u32 {
        assert_eq!(
            g.neighbor_vertices(v),
            g2.neighbor_vertices(v),
            "vertex {v}"
        );
        assert_eq!(g.neighbor_edges(v), g2.neighbor_edges(v), "vertex {v}");
    }
}

#[test]
fn every_edge_owned_exactly_once() {
    let g = GraphKind::PowerlawCluster { n: 800, m: 5, p: 0.3 }.generate(5);
    for (name, p) in [
        ("DFEP", Dfep::default().partition_graph(&g, 8, 2).unwrap()),
        ("DFEPC", Dfepc::default().partition_graph(&g, 8, 2).unwrap()),
    ] {
        p.validate(&g).unwrap();
        // one owner entry per edge, each a valid partition id, and the
        // per-part edge sets tile the edge id space exactly
        assert_eq!(p.owner.len(), g.edge_count(), "{name}");
        let mut seen = vec![0u32; g.edge_count()];
        for set in p.edge_sets() {
            for e in set {
                seen[e as usize] += 1;
            }
        }
        assert!(
            seen.iter().all(|&c| c == 1),
            "{name}: some edge owned != once"
        );
        assert_eq!(
            p.sizes().iter().sum::<usize>(),
            g.edge_count(),
            "{name}"
        );
    }
}

#[test]
fn dfep_partition_bit_identical_across_1_2_8_threads() {
    let g = GraphKind::PowerlawCluster { n: 3_000, m: 5, p: 0.3 }.generate(7);
    let base = pool::with_threads(1, || Dfep::default().partition_graph(&g, 8, 3).unwrap());
    let r_base = metrics::evaluate(&g, &base);
    for threads in [2usize, 8] {
        let p =
            pool::with_threads(threads, || Dfep::default().partition_graph(&g, 8, 3).unwrap());
        assert_eq!(p.owner, base.owner, "{threads} threads: owners differ");
        assert_eq!(
            p.rounds, base.rounds,
            "{threads} threads: round counts differ"
        );
        let r = metrics::evaluate(&g, &p);
        assert_eq!(r.nstdev.to_bits(), r_base.nstdev.to_bits());
        assert_eq!(r.largest.to_bits(), r_base.largest.to_bits());
        assert_eq!(r.messages, r_base.messages);
        assert_eq!(r.disconnected.to_bits(), r_base.disconnected.to_bits());
    }
}

#[test]
fn dfep_round_ledger_trajectory_bit_identical_across_1_2_8_threads() {
    // Pins the round engine's full f64 trajectory — the flat money
    // ledger, owners, sizes and free-edge count after every round — not
    // just the final partition. This is what fixes the stable radix
    // sort's canonical merge order (bids: edge asc, partition asc,
    // holder registration order within): any reordering of an f64
    // accumulation in step 2, step 3 or the frontier pooling would show
    // up as a ledger bit difference on some thread count.
    let g = GraphKind::PowerlawCluster { n: 1_500, m: 5, p: 0.3 }.generate(3);
    let drive = |threads: usize| {
        pool::with_threads(threads, || {
            let mut rng = Rng::new(5);
            let initial = (g.edge_count() as f64 / 8.0).max(1.0);
            let mut st = DfepState::new(&g, 8, initial, &mut rng);
            let mut ledger_bits: Vec<u64> = Vec::new();
            for _ in 0..30 {
                st.funding_round(&g, None, None);
                st.coordinator_step(10.0);
                ledger_bits
                    .extend(st.money.cells().iter().map(|c| c.to_bits()));
                if st.free_edges == 0 {
                    break;
                }
            }
            (st.owner.clone(), st.sizes.clone(), st.free_edges, ledger_bits)
        })
    };
    let base = drive(1);
    for threads in [2usize, 8] {
        let r = drive(threads);
        assert_eq!(r.0, base.0, "{threads} threads: owners differ");
        assert_eq!(r.1, base.1, "{threads} threads: sizes differ");
        assert_eq!(r.2, base.2, "{threads} threads: free edges differ");
        assert_eq!(
            r.3, base.3,
            "{threads} threads: money ledger trajectory differs"
        );
    }
}

#[test]
fn dfepc_partition_bit_identical_across_1_2_8_threads() {
    // DFEPC exercises the poor/rich raid path through the same parallel
    // round; a high-diameter graph makes raids actually happen
    let g = GraphKind::RoadNetwork {
        rows: 16,
        cols: 16,
        drop: 0.2,
        subdiv: 2,
        shortcuts: 0,
    }
    .generate(4);
    let base = pool::with_threads(1, || Dfepc::default().partition_graph(&g, 6, 9).unwrap());
    for threads in [2usize, 8] {
        let p = pool::with_threads(threads, || {
            Dfepc::default().partition_graph(&g, 6, 9).unwrap()
        });
        assert_eq!(p.owner, base.owner, "{threads} threads");
        assert_eq!(p.rounds, base.rounds, "{threads} threads");
    }
}

#[test]
fn partition_view_bit_identical_across_1_2_8_threads() {
    // the parallel view build must be a pure function of the partition:
    // same per-part CSRs, replica table, frontier flags and metrics for
    // every pool width
    let g = GraphKind::PowerlawCluster { n: 2_000, m: 5, p: 0.3 }.generate(8);
    let p = pool::with_threads(1, || Dfep::default().partition_graph(&g, 8, 4).unwrap());
    let base = pool::with_threads(1, || PartitionView::build(&g, &p));
    let r_base =
        pool::with_threads(1, || metrics::evaluate_with(&g, &p, &base));
    for threads in [2usize, 8] {
        let view =
            pool::with_threads(threads, || PartitionView::build(&g, &p));
        assert_eq!(view, base, "{threads} threads: views differ");
        let r = pool::with_threads(threads, || {
            metrics::evaluate_with(&g, &p, &view)
        });
        assert_eq!(r.nstdev.to_bits(), r_base.nstdev.to_bits());
        assert_eq!(r.largest.to_bits(), r_base.largest.to_bits());
        assert_eq!(r.messages, r_base.messages);
        assert_eq!(r.disconnected.to_bits(), r_base.disconnected.to_bits());
    }
}

#[test]
fn etsch_results_and_rounds_stable_across_thread_counts() {
    let g = GraphKind::PowerlawCluster { n: 1_000, m: 4, p: 0.3 }.generate(6);
    let p = Dfep::default().partition_graph(&g, 6, 1).unwrap();
    let run = |threads: usize| {
        pool::with_threads(threads, || {
            let mut engine = Etsch::new(&g, &p);
            let dist = engine.run(&mut Sssp::new(0));
            (dist, engine.rounds_executed(), engine.stats().clone())
        })
    };
    let (d1, rounds1, stats1) = run(1);
    for threads in [2usize, 8] {
        let (d, rounds, stats) = run(threads);
        assert_eq!(d, d1, "{threads} threads: distances differ");
        assert_eq!(rounds, rounds1, "{threads} threads: rounds differ");
        assert_eq!(
            stats.messages_exchanged, stats1.messages_exchanged,
            "{threads} threads"
        );
        assert_eq!(
            stats.messages_ceiling, stats1.messages_ceiling,
            "{threads} threads"
        );
    }
    // the dense reference agrees with the change-driven path at every
    // thread count (the dirty lists are merged in fixed part order)
    let dense = pool::with_threads(1, || {
        let view = PartitionView::build(&g, &p);
        let mut engine = Etsch::from_view(&g, &view);
        let dist = engine.run_dense(&mut Sssp::new(0));
        (dist, engine.rounds_executed(), engine.stats().clone())
    });
    assert_eq!(dense.0, d1, "dense reference: distances differ");
    assert_eq!(dense.1, rounds1, "dense reference: rounds differ");
    assert_eq!(dense.2.messages_exchanged, stats1.messages_exchanged);
    assert_eq!(dense.2.messages_ceiling, stats1.messages_ceiling);
}

#[test]
fn facade_report_bit_identical_across_1_2_8_threads() {
    // the whole PartitionRequest -> RunReport facade — partitioner run,
    // shared view build, metric evaluation and the attached workload —
    // must be a pure function of the request for every pool width
    use dfep::coordinator::runs::{PartitionRequest, Workload};
    let run = |threads: usize| {
        PartitionRequest::new("dfep")
            .unwrap()
            .dataset("plc:n=2000,m=5,p=0.3")
            .k(8)
            .seed(4)
            .graph_seed(8)
            .gain_samples(2)
            .threads(threads)
            .workload(Workload::Sssp { source: 0 })
            .execute()
            .unwrap()
    };
    let base = run(1);
    for threads in [2usize, 8] {
        let r = run(threads);
        assert_eq!(
            r.partition.owner, base.partition.owner,
            "{threads} threads: owners differ"
        );
        assert_eq!(r.partition.rounds, base.partition.rounds);
        assert_eq!(
            r.metrics.nstdev.to_bits(),
            base.metrics.nstdev.to_bits(),
            "{threads} threads"
        );
        assert_eq!(
            r.metrics.largest.to_bits(),
            base.metrics.largest.to_bits()
        );
        assert_eq!(r.metrics.messages, base.metrics.messages);
        assert_eq!(
            r.gain.unwrap().to_bits(),
            base.gain.unwrap().to_bits(),
            "{threads} threads: gain differs"
        );
        let (w, wb) =
            (r.workload.as_ref().unwrap(), base.workload.as_ref().unwrap());
        assert_eq!(w.rounds, wb.rounds, "{threads} threads: workload rounds");
        assert_eq!(w.messages, wb.messages);
        assert_eq!(w.reached, wb.reached);
    }
}
