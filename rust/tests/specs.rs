//! Spec/registry contract tests (see DESIGN.md "Partitioner specs &
//! registry"): every registered name round-trips through `Display`,
//! malformed specs fail with the documented messages, and every
//! registered spec with default parameters yields a `validate`-clean
//! partition on the generator graphs at k in {1, 2, 8}.

use dfep::partition::spec::{self, PartitionerSpec};
use dfep::partition::{registry, Partitioner};
use dfep::testing::prop::forall;

#[test]
fn every_registry_name_round_trips_through_display() {
    for e in registry::all() {
        // bare name
        let s = PartitionerSpec::parse(e.name).unwrap();
        assert_eq!(s.to_string(), e.name);
        let re: PartitionerSpec = s.to_string().parse().unwrap();
        assert_eq!(s, re, "{}", e.name);
        assert_eq!(s, spec::default_spec(e), "{}", e.name);
        // every parameter, set to its own default, round-trips too
        for p in e.params {
            let text = format!("{}:{}={}", e.name, p.key, p.default);
            let s = PartitionerSpec::parse(&text).unwrap();
            assert_eq!(s.to_string(), text, "{}:{}", e.name, p.key);
            let re: PartitionerSpec = s.to_string().parse().unwrap();
            assert_eq!(s, re, "{}:{}", e.name, p.key);
        }
        // aliases canonicalize to the registry name
        for a in e.aliases {
            assert_eq!(
                PartitionerSpec::parse(a).unwrap().to_string(),
                e.name,
                "alias {a}"
            );
        }
    }
}

/// The documented error-message table (DESIGN.md "Partitioner specs &
/// registry"): the acceptance-bar cases plus one of each error class.
#[test]
fn malformed_specs_fail_with_documented_messages() {
    let err = |s: &str| PartitionerSpec::parse(s).unwrap_err().to_string();
    // unknown algorithm lists the known names
    let e = err("nosuch");
    assert!(e.starts_with("unknown partitioner 'nosuch' (known: "), "{e}");
    for entry in registry::all() {
        assert!(e.contains(entry.name), "{e} missing {}", entry.name);
    }
    // unparsable value names the parameter and the expected type
    assert_eq!(
        err("hdrf:lambda=abc"),
        "hdrf: parameter 'lambda': expected a float, got 'abc'"
    );
    // unknown key lists the available keys
    assert_eq!(
        err("hdrf:nope=3"),
        "hdrf: unknown parameter 'nope' (available: lambda, epsilon, \
         group, chunk)"
    );
    // missing '=' is called out as a malformed pair
    assert_eq!(
        err("dfep:cap"),
        "dfep: bad parameter 'cap' (expected key=value)"
    );
    // duplicates are rejected rather than silently last-wins
    assert_eq!(
        err("dbh:chunk=1,chunk=2"),
        "dbh: duplicate parameter 'chunk'"
    );
    // range violations name the bound
    assert_eq!(
        err("restream:passes=0"),
        "restream: parameter 'passes' must be >= 1 (got 0)"
    );
    // nested-spec rows (the refine meta-spec): inner errors surface
    // prefixed, self-nesting and range violations are documented too
    let e = err("refine:base=nosuch");
    assert!(
        e.starts_with(
            "refine: parameter 'base': unknown partitioner 'nosuch' (known: "
        ),
        "{e}"
    );
    assert_eq!(
        err("refine:base=hdrf:lambda=abc"),
        "refine: parameter 'base': hdrf: parameter 'lambda': expected a \
         float, got 'abc'"
    );
    assert_eq!(
        err("refine:base=refine"),
        "refine: parameter 'base' must not name 'refine' itself"
    );
    assert_eq!(
        err("refine:rounds=0"),
        "refine: parameter 'rounds' must be >= 1 (got 0)"
    );
}

/// The refine meta-spec's composed grammar: a parameterized nested spec
/// round-trips through `Display`, and the canonical (cache-key) form
/// elaborates the nested spec recursively, so every spelling of one
/// configuration shares a serve-cache entry.
#[test]
fn refine_nested_specs_round_trip_and_share_cache_keys() {
    let s: PartitionerSpec = "refine:base=hdrf:lambda=1.50+group=512,rounds=2"
        .parse()
        .unwrap();
    assert_eq!(
        s.to_string(),
        "refine:base=hdrf:lambda=1.5+group=512,rounds=2"
    );
    assert_eq!(s, s.to_string().parse().unwrap());
    // bare name, alias, and inner-default spellings all collide
    let bare: PartitionerSpec = "refine".parse().unwrap();
    let alias: PartitionerSpec = "local-search".parse().unwrap();
    let inner_default: PartitionerSpec =
        "refine:base=hdrf:lambda=1.1".parse().unwrap();
    assert_eq!(bare.canonical(), alias.canonical());
    assert_eq!(bare.canonical(), inner_default.canonical());
    // a tuned inner spec is a different key
    let tuned: PartitionerSpec =
        "refine:base=hdrf:lambda=1.5".parse().unwrap();
    assert_ne!(tuned.canonical(), bare.canonical());
}

/// The DESIGN.md registry table (also diffed row-by-row by a unit test
/// in `partition::registry`) must carry the refine entry: catching a
/// drifted or missing row at the integration tier too keeps the docs
/// honest when only tier-1 runs.
#[test]
fn design_md_registry_table_includes_every_entry() {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("../DESIGN.md");
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("cannot read {}: {e}", path.display()));
    for e in registry::all() {
        let row = format!("| `{}` | ", e.name);
        assert!(
            text.contains(&row),
            "DESIGN.md registry table has no row for '{}'",
            e.name
        );
        for p in e.params {
            let cell = format!("`{}={}`", p.key, p.default);
            assert!(
                text.contains(&cell),
                "DESIGN.md registry table missing {} cell {cell}",
                e.name
            );
        }
    }
}

#[test]
fn every_default_spec_partitions_generator_graphs_cleanly() {
    // the satellite property: every registered spec, default params,
    // produces a validate-clean complete cover at k in {1, 2, 8}
    forall(6, |g| {
        let graph = g.any_graph(12, 110);
        let part_seed: u64 = g.rng.next_u64();
        for e in registry::all() {
            // cap JaBeJa's rounds so the property suite stays fast; all
            // other entries run with pure defaults
            let s = if e.name == "jabeja" {
                PartitionerSpec::parse("jabeja:rounds=10").unwrap()
            } else {
                spec::default_spec(e)
            };
            let p = s.build();
            assert_eq!(p.streaming_native(), e.streaming_native, "{}", e.name);
            for k in [1usize, 2, 8] {
                let part = p
                    .partition_graph(&graph, k, part_seed)
                    .unwrap_or_else(|err| panic!("{} k={k}: {err}", e.name));
                part.validate(&graph).unwrap_or_else(|err| {
                    panic!("{} k={k}: {err}", e.name)
                });
                assert_eq!(
                    part.sizes().iter().sum::<usize>(),
                    graph.edge_count(),
                    "{} k={k} loses edges",
                    e.name
                );
            }
            // k = 0 is an error, never a panic
            assert!(
                p.partition_graph(&graph, 0, part_seed).is_err(),
                "{} accepted k=0",
                e.name
            );
        }
    });
}
