//! Serving-layer integration tests: a real `Server` on an ephemeral
//! loopback port, hammered by real `ServeClient`s over TCP.
//!
//! The load-bearing assertions:
//! - served reports are *bit-identical* to a direct in-process
//!   `PartitionRequest::execute` (owners vector and float metrics);
//! - concurrent identical requests are single-flight — the `/stats`
//!   `computations` probe counter equals the number of distinct cache
//!   keys, not the number of requests;
//! - spelling variants of one spec (`hdrf` vs `hdrf:lambda=1.1`) share
//!   one cache entry (canonical-form keys);
//! - every documented error class answers its documented status code
//!   and machine-readable kind.

use dfep::coordinator::batch::{BatchRequest, Variant};
use dfep::coordinator::runs::{PartitionRequest, RunReport};
use dfep::coordinator::serve::{ServeClient, ServeConfig, Server};
use dfep::util::error::ErrorKind;

/// Spawn a server on an ephemeral port with a small body limit (keeps
/// the oversized-request test cheap).
fn spawn() -> dfep::coordinator::serve::ServeHandle {
    Server::spawn(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 4,
        max_body_bytes: 4096,
        ..Default::default()
    })
    .unwrap()
}

fn stat(client: &mut ServeClient, key: &str) -> f64 {
    let (status, body) = client.get("/stats").unwrap();
    assert_eq!(status, 200, "{body}");
    dfep::util::json::parse(&body)
        .unwrap()
        .get(key)
        .unwrap_or_else(|| panic!("no '{key}' in {body}"))
        .as_f64()
        .unwrap()
}

fn kind_of(body: &str) -> String {
    dfep::util::json::parse(body)
        .unwrap()
        .get("kind")
        .and_then(|v| v.as_str().map(str::to_string))
        .unwrap_or_else(|| panic!("no 'kind' in {body}"))
}

#[test]
fn healthz_stats_and_routing_on_one_keep_alive_connection() {
    let server = spawn();
    let mut c = ServeClient::connect(server.addr());
    // several requests ride one connection (keep-alive)
    let (status, body) = c.get("/healthz").unwrap();
    assert_eq!(status, 200);
    assert!(body.contains("true"), "{body}");
    let (status, _body) = c.get("/healthz").unwrap();
    assert_eq!(status, 200);
    // unknown path
    let (status, body) = c.get("/nope").unwrap();
    assert_eq!(status, 404);
    assert_eq!(kind_of(&body), "invalid_request");
    // wrong method on a real endpoint
    let (status, body) = c.request("GET", "/partition", b"").unwrap();
    assert_eq!(status, 405);
    assert_eq!(kind_of(&body), "invalid_request");
    // stats counted all of the above
    assert!(stat(&mut c, "requests_total") >= 4.0);
    assert_eq!(stat(&mut c, "computations"), 0.0);
}

#[test]
fn served_report_is_bit_identical_to_direct_execution() {
    let server = spawn();
    let req = PartitionRequest::new("dfep").unwrap().dataset("er:n=300,m=900").k(6).seed(3);
    let direct = req.execute().unwrap();
    let mut c = ServeClient::connect(server.addr());
    let served = c.partition(&req, true).unwrap();
    assert_eq!(served.partition.owner, direct.partition.owner);
    assert_eq!(served.spec, direct.spec);
    assert_eq!(served.dataset, direct.dataset);
    assert_eq!(served.vertices, direct.vertices);
    assert_eq!(served.edges, direct.edges);
    assert_eq!(served.metrics.nstdev.to_bits(), direct.metrics.nstdev.to_bits());
    assert_eq!(served.metrics.largest.to_bits(), direct.metrics.largest.to_bits());
    assert_eq!(served.metrics.messages, direct.metrics.messages);
    assert_eq!(served.metrics.rounds, direct.metrics.rounds);
    // the repeat is a cache hit, not a second computation
    let again = c.partition(&req, true).unwrap();
    assert_eq!(again.partition.owner, direct.partition.owner);
    assert_eq!(stat(&mut c, "computations"), 1.0);
    assert!(stat(&mut c, "cache_hits") >= 1.0);
}

#[test]
fn concurrent_identical_and_broken_requests_single_flight() {
    let server = spawn();
    let addr = server.addr();
    let req = PartitionRequest::new("dfep").unwrap().dataset("er:n=400,m=1200").k(8).seed(11);
    let owners: Vec<Vec<u32>> = std::thread::scope(|s| {
        let mut valid = Vec::new();
        let mut broken = Vec::new();
        for i in 0..12usize {
            let req = &req;
            match i % 3 {
                0 => valid.push(s.spawn(move || {
                    let mut c = ServeClient::connect(addr);
                    c.partition(req, true).unwrap().partition.owner
                })),
                1 => broken.push(s.spawn(move || {
                    // malformed JSON: 400 invalid_request, and never
                    // reaches the computation path
                    let mut c = ServeClient::connect(addr);
                    let (status, body) = c.request("POST", "/partition", b"{ not json").unwrap();
                    assert_eq!(status, 400, "{body}");
                    assert_eq!(kind_of(&body), "invalid_request");
                })),
                _ => broken.push(s.spawn(move || {
                    // body over the server's limit: 413 at the wire
                    let mut c = ServeClient::connect(addr);
                    let big = vec![b'x'; 8192];
                    let (status, body) = c.request("POST", "/partition", &big).unwrap();
                    assert_eq!(status, 413, "{body}");
                    assert_eq!(kind_of(&body), "invalid_request");
                })),
            }
        }
        for t in broken {
            t.join().unwrap();
        }
        valid.into_iter().map(|t| t.join().unwrap()).collect()
    });
    // all concurrent identical requests saw the same owners...
    for o in &owners[1..] {
        assert_eq!(o, &owners[0]);
    }
    // ...served by exactly ONE computation (single flight): the probe
    // counter equals the distinct-key count
    let mut c = ServeClient::connect(addr);
    assert_eq!(stat(&mut c, "computations"), 1.0);
    // >= because the client SDK may retry a shed request once
    assert!(stat(&mut c, "shed_body_too_large") >= 4.0);
    assert_eq!(stat(&mut c, "computations_in_flight"), 0.0);
}

#[test]
fn spelling_variants_share_one_cache_entry() {
    let server = spawn();
    let mut c = ServeClient::connect(server.addr());
    let run = |c: &mut ServeClient, spec: &str| {
        let req = PartitionRequest::new(spec).unwrap().dataset("er:n=200,m=600").k(4).seed(7);
        c.partition(&req, false).unwrap()
    };
    let a = run(&mut c, "hdrf");
    // explicit-default and padded spellings hit the same entry
    let b = run(&mut c, "hdrf:lambda=1.1");
    let d = run(&mut c, "hdrf:lambda=1.10");
    assert_eq!(a.metrics.nstdev.to_bits(), b.metrics.nstdev.to_bits());
    assert_eq!(a.metrics.nstdev.to_bits(), d.metrics.nstdev.to_bits());
    assert_eq!(stat(&mut c, "computations"), 1.0);
    assert_eq!(stat(&mut c, "cache_hits"), 2.0);
    // a real parameter change is a different key
    let _ = run(&mut c, "hdrf:lambda=1.5");
    assert_eq!(stat(&mut c, "computations"), 2.0);
}

#[test]
fn batch_endpoint_shares_the_result_cache_with_partition() {
    let server = spawn();
    let mut c = ServeClient::connect(server.addr());
    // warm one variant through the single-run endpoint
    let warm =
        PartitionRequest::new("dfep").unwrap().dataset("er:n=300,m=900").k(4).seed(1);
    let direct = c.partition(&warm, true).unwrap();
    assert_eq!(stat(&mut c, "computations"), 1.0);
    // a batch where exactly one variant is already cached
    let breq = BatchRequest::new("er:n=300,m=900")
        .variant(Variant::new("dfep", 4, 1).unwrap())
        .variant(Variant::new("dfep", 4, 2).unwrap())
        .variant(Variant::new("random", 4, 1).unwrap());
    let rep = c.batch(&breq).unwrap();
    assert_eq!(rep.reports.len(), 3);
    assert_eq!(rep.dataset, "er:n=300,m=900");
    // the cached variant came back bit-identical to the direct run
    assert_eq!(rep.reports[0].partition.owner, direct.partition.owner);
    assert_eq!(
        rep.reports[0].metrics.nstdev.to_bits(),
        direct.metrics.nstdev.to_bits()
    );
    // only the two misses computed, and the hit was counted
    assert_eq!(stat(&mut c, "computations"), 3.0);
    assert!(stat(&mut c, "cache_hits") >= 1.0);
    // the batch published its misses: a follow-up /partition is a hit
    let follow = breq.request_for(&breq.variants[1]);
    let served = c.partition(&follow, true).unwrap();
    assert_eq!(served.partition.owner, rep.reports[1].partition.owner);
    assert_eq!(stat(&mut c, "computations"), 3.0);
    // an all-hit repeat computes nothing
    let again = c.batch(&breq).unwrap();
    assert_eq!(again.reports.len(), 3);
    assert_eq!(again.reports[2].partition.owner, rep.reports[2].partition.owner);
    assert_eq!(stat(&mut c, "computations"), 3.0);
    // resolve attribution: the graph was built exactly once, and its
    // cost is visible separately from partitioning
    assert_eq!(stat(&mut c, "resolve_count"), 1.0);
    assert!(stat(&mut c, "resolve_max_ms") >= 0.0);
}

#[test]
fn batch_endpoint_rejects_bad_requests_with_documented_kinds() {
    let server = spawn();
    let mut c = ServeClient::connect(server.addr());
    // empty variant list -> 400 invalid_request
    let empty = BatchRequest::new("er:n=100,m=300");
    let (status, body) =
        c.request("POST", "/batch", empty.to_json().as_bytes()).unwrap();
    assert_eq!(status, 400, "{body}");
    assert_eq!(kind_of(&body), "invalid_request");
    // unknown dataset -> 404 dataset_not_found (typed through the SDK)
    let missing = BatchRequest::new("nosuchgraph")
        .variant(Variant::new("dfep", 2, 1).unwrap());
    let err = c.batch(&missing).unwrap_err();
    assert_eq!(err.kind(), ErrorKind::DatasetNotFound);
    // wrong method on the endpoint -> 405
    let (status, body) = c.request("GET", "/batch", b"").unwrap();
    assert_eq!(status, 405);
    assert_eq!(kind_of(&body), "invalid_request");
    // nothing above ever computed
    assert_eq!(stat(&mut c, "computations"), 0.0);
}

#[test]
fn error_codes_follow_the_documented_kind_table() {
    let server = spawn();
    let mut c = ServeClient::connect(server.addr());
    let post = |c: &mut ServeClient, body: &str| {
        let (status, body) = c.request("POST", "/partition", body.as_bytes()).unwrap();
        (status, kind_of(&body))
    };
    // bad spec string -> 400 invalid_spec
    let req = PartitionRequest::new("dfep").unwrap().dataset("er:n=100,m=300").k(2);
    let bad_spec = req.to_json().replace("\"dfep\"", "\"hdrf:lambda=abc\"");
    assert_eq!(post(&mut c, &bad_spec), (400, "invalid_spec".to_string()));
    // unknown dataset -> 404 dataset_not_found
    let bad_ds = req.to_json().replace("er:n=100,m=300", "nosuchgraph");
    assert_eq!(post(&mut c, &bad_ds), (404, "dataset_not_found".to_string()));
    // unknown field -> 400 invalid_request (strict wire requests)
    let extra = req.to_json().replace("\"k\"", "\"kay\"");
    assert_eq!(post(&mut c, &extra), (400, "invalid_request".to_string()));
    // the client SDK surfaces the kind on its typed error
    let mut bad = PartitionRequest::new("dfep").unwrap().k(2);
    bad = bad.dataset("nosuchgraph");
    let err = c.partition(&bad, false).unwrap_err();
    assert_eq!(err.kind(), ErrorKind::DatasetNotFound);
    // nothing above ever computed
    assert_eq!(stat(&mut c, "computations"), 0.0);
}

#[test]
fn wire_json_negative_paths_are_typed() {
    // requests parse STRICTLY: an unknown field is a typed reject with
    // the documented message, not a silently-defaulted experiment
    let err = PartitionRequest::from_json(
        r#"{"v":1,"spec":"dfep","dataset":"er:n=100,m=300","kay":2}"#,
    )
    .unwrap_err();
    assert_eq!(err.kind(), ErrorKind::InvalidRequest);
    assert!(
        err.to_string()
            .starts_with("unknown request field 'kay' (known: v,"),
        "{err}"
    );
    // any version other than (a missing) 1 is rejected
    let err = PartitionRequest::from_json(
        r#"{"v":2,"spec":"dfep","dataset":"er:n=100,m=300"}"#,
    )
    .unwrap_err();
    assert_eq!(err.kind(), ErrorKind::InvalidRequest);
    assert_eq!(
        err.to_string(),
        "unsupported wire version (this crate speaks v=1)"
    );
    // a bad spec inside an otherwise-valid request is InvalidSpec, not
    // InvalidRequest — the serve layer's 400 sub-kinds stay distinct
    let err = PartitionRequest::from_json(
        r#"{"v":1,"spec":"refine:base=nosuch","dataset":"er:n=100,m=300"}"#,
    )
    .unwrap_err();
    assert_eq!(err.kind(), ErrorKind::InvalidSpec);
    // reports parse LENIENTLY: a field added by a future server is
    // ignored, everything this client knows still round-trips
    let req = PartitionRequest::new("dfep")
        .unwrap()
        .dataset("er:n=100,m=300")
        .k(2)
        .seed(1);
    let report = req.execute().unwrap();
    let extra = report
        .to_json_with_owners()
        .replacen('{', "{\"future_field\": \"yes\", ", 1);
    let parsed = RunReport::from_json(&extra).unwrap();
    assert_eq!(parsed.partition.owner, report.partition.owner);
    assert_eq!(parsed.spec, report.spec);
    assert_eq!(parsed.edges, report.edges);
    assert_eq!(
        parsed.metrics.nstdev.to_bits(),
        report.metrics.nstdev.to_bits()
    );
}

#[test]
fn malformed_refine_specs_answer_invalid_spec_through_the_wire() {
    let server = spawn();
    let mut c = ServeClient::connect(server.addr());
    let post = |c: &mut ServeClient, body: &str| {
        let (status, body) =
            c.request("POST", "/partition", body.as_bytes()).unwrap();
        (status, kind_of(&body))
    };
    let req = PartitionRequest::new("dfep")
        .unwrap()
        .dataset("er:n=100,m=300")
        .k(2);
    // every documented refine grammar error maps to 400 invalid_spec:
    // unknown inner name, self-nesting, out-of-range parameter
    for bad in ["refine:base=nosuch", "refine:base=refine", "refine:rounds=0"]
    {
        let body =
            req.to_json().replace("\"dfep\"", &format!("\"{bad}\""));
        assert_eq!(
            post(&mut c, &body),
            (400, "invalid_spec".to_string()),
            "{bad}"
        );
    }
    assert_eq!(stat(&mut c, "computations"), 0.0);
    // and a well-formed composed spec (parameterized nested base) runs
    // end-to-end over the wire
    let ok = PartitionRequest::new("refine:base=hdrf:lambda=1.5,rounds=2")
        .unwrap()
        .dataset("er:n=200,m=600")
        .k(4)
        .seed(7);
    let rep = c.partition(&ok, true).unwrap();
    assert_eq!(rep.partition.owner.len(), 600);
    assert_eq!(stat(&mut c, "computations"), 1.0);
}
