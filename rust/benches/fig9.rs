//! Regenerates the paper's fig9 series — see bench::figures::fig9_with.
//! Drives every sweep cell through the batch engine (coordinator::batch)
//! and emits BENCH_fig9.json (override: DFEP_FIG_OUT).
//! Knobs: DFEP_SAMPLES (default 5; paper 100), DFEP_SCALE (default 0.05).
//!
//! `--quick` (or DFEP_QUICK=1) is the CI smoke mode: fewer cells, one
//! sample, same artifact schema. Other flags (cargo bench passes
//! `--bench`) are ignored.
fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("DFEP_QUICK").map(|v| v == "1").unwrap_or(false);
    dfep::bench::figures::fig9_with(quick);
}
