//! Ablation sweep beyond the paper's comparison: every partitioner in the
//! crate (including the streaming Fennel-style and multilevel METIS-style
//! baselines from the paper's related-work section) on a small-world and a
//! road graph, plus the DFEP design-choice ablations (frontier-first off,
//! funding cap, initial fraction) and cluster fault injection.
//! Knobs: DFEP_SAMPLES, DFEP_SCALE.

use dfep::bench::figures::{measure, samples, scale, spec};
use dfep::bench::{fmt_f, Table};
use dfep::cluster::cost::CostModel;
use dfep::cluster::dfep_mr::run_cluster_dfep;
use dfep::cluster::failures::{simulate_with_faults, FaultModel};
use dfep::graph::datasets;
use dfep::partition::registry;

fn main() {
    let n = samples();
    let sc = scale();

    println!("=== all-partitioner sweep (K=20, samples={n}, scale={sc}) ===");
    for ds in ["astroph", "usroads"] {
        let d = datasets::by_name(ds).unwrap();
        let g = if sc >= 1.0 { d.generate(42) } else { d.scaled(sc, 42) };
        println!("\n[{ds}] |V|={} |E|={}", g.vertex_count(), g.edge_count());
        let mut t = Table::new(&[
            "algo", "largest", "nstdev", "messages", "rounds", "gain",
        ]);
        for entry in registry::all() {
            let s = spec(entry.name);
            let c = measure(&g, &s, 20, n, 2);
            t.row(&[
                entry.name.into(),
                fmt_f(c.largest.mean),
                fmt_f(c.nstdev.mean),
                fmt_f(c.messages.mean),
                fmt_f(c.rounds.mean),
                fmt_f(c.gain.mean),
            ]);
        }
    }

    println!("\n=== DFEP design-choice ablations (astroph, K=20) ===");
    {
        let g = datasets::astroph().scaled(sc, 42);
        let mut t = Table::new(&[
            "variant", "largest", "nstdev", "messages", "rounds",
        ]);
        // every ablation variant is a spec string now — the same
        // grammar the CLI takes
        let variants = vec![
            ("default", "dfep"),
            (
                "literal Alg.4 (no frontier-first)",
                "dfep:frontier_first=false,max_rounds=300",
            ),
            ("initial x0.25", "dfep:init=0.25"),
            ("initial x4", "dfep:init=4"),
            ("cap=2", "dfep:cap=2"),
            ("cap=50", "dfep:cap=50"),
        ];
        for (name, v) in variants {
            let c = measure(&g, &spec(v), 20, n, 0);
            t.row(&[
                name.into(),
                fmt_f(c.largest.mean),
                fmt_f(c.nstdev.mean),
                fmt_f(c.messages.mean),
                fmt_f(c.rounds.mean),
            ]);
        }
        println!(
            "(paper §IV: smaller initial funding \"would not decrease the \
             precision... but it would slow it down during the first \
             rounds\" — compare rounds across initial fractions)"
        );
    }

    println!("\n=== cluster fault injection (DFEP job, dblp@{sc}) ===");
    {
        let g = datasets::dblp().scaled(sc.min(0.25), 42);
        let cost = CostModel::default();
        let run = run_cluster_dfep(&g, 20, 8, 7, &cost, 2000);
        let mut t = Table::new(&[
            "fault model", "nodes", "time_s", "overhead%", "failures",
        ]);
        for (name, fm) in [
            (
                "none",
                FaultModel {
                    node_failure_per_round: 0.0,
                    straggler_per_round: 0.0,
                    ..Default::default()
                },
            ),
            ("default", FaultModel::default()),
            (
                "flaky (1% node-round)",
                FaultModel {
                    node_failure_per_round: 0.01,
                    ..Default::default()
                },
            ),
        ] {
            for nodes in [4usize, 16] {
                let clean: f64 = run
                    .work
                    .iter()
                    .map(|&w| cost.round_time(nodes, w))
                    .sum();
                let f = simulate_with_faults(
                    &cost, &fm, nodes, &run.work, 11,
                );
                t.row(&[
                    name.into(),
                    nodes.to_string(),
                    fmt_f(f.total_time),
                    fmt_f((f.total_time / clean - 1.0) * 100.0),
                    f.failures.to_string(),
                ]);
            }
        }
    }
}
