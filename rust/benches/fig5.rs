//! Regenerates the paper's fig5 series — see bench::figures::fig5.
//! Knobs: DFEP_SAMPLES (default 5; paper 100), DFEP_SCALE (default 0.05).
fn main() {
    dfep::bench::figures::fig5();
}
