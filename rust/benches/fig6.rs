//! Regenerates the paper's fig6 series — see bench::figures::fig6.
//! Knobs: DFEP_SAMPLES (default 5; paper 100), DFEP_SCALE (default 0.05).
fn main() {
    dfep::bench::figures::fig6();
}
