//! Distributed-runtime bench — see bench::cluster_load: a real
//! coordinator + in-process workers over loopback TCP, reporting round
//! latency, measured-vs-predicted wire bytes per phase, and
//! kill-and-recover wall-clock into BENCH_cluster.json (override:
//! DFEP_CLUSTER_OUT).
//!
//! `--quick` (or DFEP_QUICK=1) is the CI smoke mode: a smaller graph,
//! same artifact shape. Other flags (cargo bench passes `--bench`) are
//! ignored.
fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("DFEP_QUICK").map(|v| v == "1").unwrap_or(false);
    dfep::bench::cluster_load::cluster_load_with(quick);
}
