//! Regenerates the hotpath series — see bench::figures::hotpath_with:
//! DFEP thread scaling, the dfep_round series (round-engine rounds/sec,
//! edges-bought/sec and peak scratch bytes of the persistent
//! RoundScratch), the partition_view derived-state series, and the
//! streaming series (edges/sec for the ingest-time hdrf / dbh / restream
//! partitioners, with StreamingGreedy as the materialized comparison).
//! Knobs: DFEP_SAMPLES (default 5; paper 100), DFEP_SCALE (default 0.05),
//! DFEP_BENCH_OUT (default BENCH_hotpath.json).
//!
//! `--quick` (or DFEP_QUICK=1) is the CI smoke mode: small graph, one
//! repetition, still emitting the JSON artifact. Other flags (cargo
//! bench passes `--bench`) are ignored.
fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("DFEP_QUICK").map(|v| v == "1").unwrap_or(false);
    dfep::bench::figures::hotpath_with(quick);
}
