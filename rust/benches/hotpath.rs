//! Regenerates the paper's hotpath series — see bench::figures::hotpath.
//! Knobs: DFEP_SAMPLES (default 5; paper 100), DFEP_SCALE (default 0.05).
fn main() {
    dfep::bench::figures::hotpath();
}
