//! Load-generator bench for `repro serve` — see bench::serve_load:
//! closed-loop clients against an in-process server, ~90/10 hot/cold key
//! mix, emitting req/s + p50/p99 (overall and per mix) plus the server's
//! cache counters into BENCH_serve.json (override: DFEP_SERVE_OUT).
//!
//! `--quick` (or DFEP_QUICK=1) is the CI smoke mode: fewer clients and
//! requests, same artifact shape. Other flags (cargo bench passes
//! `--bench`) are ignored.
fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("DFEP_QUICK").map(|v| v == "1").unwrap_or(false);
    dfep::bench::serve_load::serve_load_with(quick);
}
