//! Regenerates the paper's tables series — see bench::figures::tables.
//! Knobs: DFEP_SAMPLES (default 5; paper 100), DFEP_SCALE (default 0.05).
fn main() {
    dfep::bench::figures::tables();
}
