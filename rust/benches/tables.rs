//! Regenerates the paper's Tables II/III calibration — see
//! bench::figures::tables_with. Emits BENCH_tables.json (override:
//! DFEP_FIG_OUT).
//! Knobs: DFEP_SAMPLES (default 5; paper 100), DFEP_SCALE (default 0.05).
//!
//! `--quick` (or DFEP_QUICK=1) is the CI smoke mode: simulation datasets
//! only, same artifact schema. Other flags (cargo bench passes
//! `--bench`) are ignored.
fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("DFEP_QUICK").map(|v| v == "1").unwrap_or(false);
    dfep::bench::figures::tables_with(quick);
}
