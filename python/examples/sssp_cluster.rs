//! placeholder — replaced by the real example.
fn main() { println!("sssp_cluster: TODO"); }
