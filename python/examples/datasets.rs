//! placeholder — replaced by the real example.
fn main() { println!("datasets: TODO"); }
