//! placeholder — replaced by the real example.
fn main() { println!("diameter_study: TODO"); }
