//! placeholder — replaced by the real example.
fn main() { println!("partition_compare: TODO"); }
