//! placeholder — replaced by the real example.
fn main() { println!("xla_engine: TODO"); }
