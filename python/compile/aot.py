"""AOT: lower every registry entry to HLO **text** + a manifest for rust.

HLO text (NOT ``lowered.compile().serialize()`` / serialized HloModuleProto)
is the interchange format: jax >= 0.5 emits protos with 64-bit instruction
ids which xla_extension 0.5.1 (the version the published ``xla`` 0.1.6
crate binds) rejects (``proto.id() <= INT_MAX``). The text parser reassigns
ids, so text round-trips cleanly. See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Outputs:
  artifacts/<name>.hlo.txt   one per registry entry
  artifacts/manifest.json    shapes/dtypes per artifact, read by rust runtime
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
from jax._src.lib import xla_client as xc

from .model import artifact_registry


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_name(d) -> str:
    return {"float32": "f32", "int32": "i32", "uint8": "u8",
            "bool": "pred"}.get(str(d), str(d))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated artifact names (default: all)")
    args = ap.parse_args()
    out = pathlib.Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)

    selected = set(args.only.split(",")) if args.only else None
    manifest = {}
    for name, (fn, specs) in artifact_registry().items():
        if selected is not None and name not in selected:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = out / f"{name}.hlo.txt"
        path.write_text(text)
        out_shapes = [
            {"shape": list(s.shape), "dtype": _dtype_name(s.dtype)}
            for s in jax.eval_shape(fn, *specs)
        ]
        manifest[name] = {
            "file": path.name,
            "inputs": [{"shape": list(s.shape), "dtype": _dtype_name(s.dtype)}
                       for s in specs],
            "outputs": out_shapes,
        }
        print(f"  {name}: {len(text)} chars, "
              f"{len(specs)} inputs -> {len(out_shapes)} outputs")

    mpath = out / "manifest.json"
    # Merge with an existing manifest when --only was used.
    if selected is not None and mpath.exists():
        old = json.loads(mpath.read_text())
        old.update(manifest)
        manifest = old
    mpath.write_text(json.dumps(manifest, indent=2, sort_keys=True))
    print(f"wrote {mpath} ({len(manifest)} artifacts)")


if __name__ == "__main__":
    main()
