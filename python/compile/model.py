"""Layer-2 JAX compute graphs (build-time only; AOT'd to HLO by aot.py).

Two families:

1. **Relaxation** — the ETSCH local-computation phase as tropical-semiring
   fixpoint sweeps over a partition's dense adjacency blocks. Calls the
   Layer-1 Pallas kernels (kernels.minplus), so the Pallas code lowers into
   the same HLO module the rust runtime executes.

2. **Funding** — DFEP steps 1+2 (vertex funding propagation + edge auction)
   vectorized over all K partitions on a statically-padded edge list. Step 3
   (the coordinator's centralized funding injection) stays in rust, matching
   the paper's structure: "step 3, while centralized, needs an amount of
   computation that is only linear in the number of partitions".

Conventions shared with the rust runtime (see rust/src/runtime/):
  * tropical zero is ``INF32`` (a large finite f32, not +inf) so padded
    rows/cols are inert and integer casts stay total;
  * padded edges carry ``owner = -2`` and are never eligible;
  * free edges carry ``owner = -1``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.minplus import minplus_mv, minplus_mm


# --------------------------------------------------------------------------
# Relaxation (ETSCH local computation)
# --------------------------------------------------------------------------

def relax_step(a: jax.Array, x: jax.Array) -> jax.Array:
    """One Bellman-Ford sweep on a partition block: x <- min(x, A ⊗ x)."""
    return jnp.minimum(x, minplus_mv(a, x))


def relax_while(a: jax.Array, x: jax.Array, max_steps: int):
    """Sweep to fixpoint (or ``max_steps``), returning (x, steps_used).

    A ``while_loop`` rather than ``scan`` so a converged partition stops
    paying for sweeps — partitions produced by DFEP are connected with
    small effective diameter, so typical step counts are far below the
    worst-case bound the caller passes.
    """

    def cond(state):
        _, changed, t = state
        return jnp.logical_and(changed, t < max_steps)

    def body(state):
        x, _, t = state
        nx = relax_step(a, x)
        return nx, jnp.any(nx < x), t + 1

    x, _, steps = jax.lax.while_loop(cond, body, (x, jnp.bool_(True),
                                                  jnp.int32(0)))
    return x, steps


def multi_source_step(a: jax.Array, b: jax.Array) -> jax.Array:
    """One sweep for many sources at once: B <- min(B, A ⊗ B)."""
    return jnp.minimum(b, minplus_mm(a, b))


def multi_relax_while(a: jax.Array, b: jax.Array, max_steps: int):
    """Multi-source fixpoint: every column of B is an independent source
    vector; used by betweenness-style all-sources-at-once sweeps."""

    def cond(state):
        _, changed, t = state
        return jnp.logical_and(changed, t < max_steps)

    def body(state):
        b, _, t = state
        nb = multi_source_step(a, b)
        return nb, jnp.any(nb < b), t + 1

    b, _, steps = jax.lax.while_loop(cond, body, (b, jnp.bool_(True),
                                                  jnp.int32(0)))
    return b, steps


# --------------------------------------------------------------------------
# DFEP funding round (steps 1 + 2), vectorized over K partitions
# --------------------------------------------------------------------------

def _scatter_add_rows(values: jax.Array, idx: jax.Array, width: int):
    """Per-row scatter-add: out[i, idx[e]] += values[i, e]  (K rows)."""

    def one(row):
        return jnp.zeros((width,), row.dtype).at[idx].add(row)

    return jax.vmap(one)(values)


def funding_step(src: jax.Array, dst: jax.Array, owner: jax.Array,
                 money: jax.Array):
    """DFEP Algorithm 4 + Algorithm 5 over the whole edge list at once.

    Args:
      src, dst: int32[E] endpoints (padded edges may point anywhere).
      owner:    int32[E]; -1 = free, -2 = padding, else partition id.
      money:    f32[K, V] per-partition per-vertex funding.

    Returns (new_owner int32[E], new_money f32[K, V], bought f32[K]) where
    ``bought[i]`` counts edges partition i won *this* round.

    Semantics notes (matching the paper's pseudocode):
      * Step 1: each vertex splits its funding equally among incident edges
        that are free or already owned by that partition; a vertex with no
        eligible incident edge *keeps* its funding (the literal pseudocode
        would destroy it — see DESIGN.md).
      * Step 2: a free edge is sold to the highest bidder iff the bid is
        >= 1 unit; the winner pays 1, the remainder returns half/half to
        the endpoints. Losing bids return to the vertices that contributed
        them. Bids on an edge you already own also return half/half (money
        keeps circulating inside the owned region, which is what lets a
        partition's frontier keep expanding).
    """
    k, v = money.shape
    valid = owner >= -1                              # bool[E], excludes padding
    free = jnp.logical_and(valid, owner == -1)       # bool[E]
    pid = jnp.arange(k, dtype=jnp.int32)[:, None]    # [K,1]

    # --- Step 1: vertex -> edge propagation (frontier-first) --------------
    # A vertex adjacent to any free edge bids only on free edges (the
    # rust engine's `frontier_first` semantics, see partition/dfep.rs);
    # otherwise it circulates funding across its own partition's edges.
    free_f = free.astype(money.dtype)
    ones = jnp.ones((k, src.shape[0]), money.dtype) * free_f[None, :]
    deg_free = (_scatter_add_rows(ones, src, v) +
                _scatter_add_rows(ones, dst, v))     # [K,V] (same per row)
    own = jnp.logical_and(valid[None, :], owner[None, :] == pid)  # [K,E]
    own_f = own.astype(money.dtype)
    deg_own = (_scatter_add_rows(own_f, src, v) +
               _scatter_add_rows(own_f, dst, v))     # [K,V]
    at_frontier = deg_free > 0                       # [K,V]
    has_own = deg_own > 0
    share_free = jnp.where(at_frontier,
                           money / jnp.maximum(deg_free, 1.0), 0.0)
    share_own = jnp.where(jnp.logical_and(~at_frontier, has_own),
                          money / jnp.maximum(deg_own, 1.0), 0.0)
    kept = jnp.where(jnp.logical_or(at_frontier, has_own), 0.0, money)
    # per-endpoint contributions: free edges take the frontier share,
    # own edges take the circulation share from non-frontier endpoints
    contrib_src = (free_f[None, :] * share_free[:, src] +
                   own_f * share_own[:, src])        # [K,E]
    contrib_dst = (free_f[None, :] * share_free[:, dst] +
                   own_f * share_own[:, dst])
    offer = contrib_src + contrib_dst                # M_i[e]
    # eligibility mask for refunds: any edge that can carry a bid
    elig = jnp.logical_or(free[None, :], own)

    # --- Step 2: edge auction ---------------------------------------------
    best = jnp.argmax(offer, axis=0).astype(jnp.int32)        # [E]
    best_offer = jnp.max(offer, axis=0)                        # [E]
    sold = jnp.logical_and(free, best_offer >= 1.0)            # [E]
    new_owner = jnp.where(sold, best, owner)

    is_winner = jnp.logical_and(sold[None, :], pid == best[None, :])  # [K,E]
    owns_unsold = jnp.logical_and(~sold[None, :], owner[None, :] == pid)
    # Winner: pay 1, split remainder half/half between the endpoints.
    # Owner of a not-for-sale edge: committed funding returns half/half.
    half_back = (jnp.where(is_winner, (offer - 1.0) * 0.5, 0.0) +
                 jnp.where(owns_unsold, offer * 0.5, 0.0))
    # Everyone else with a live bid gets an exact refund: each endpoint
    # receives back exactly what it contributed.
    refunded = jnp.logical_and(elig, ~jnp.logical_or(is_winner, owns_unsold))
    refund_f = refunded.astype(money.dtype)
    back_src = half_back + refund_f * contrib_src
    back_dst = half_back + refund_f * contrib_dst

    new_money = (kept +
                 _scatter_add_rows(back_src, src, v) +
                 _scatter_add_rows(back_dst, dst, v))
    bought = jnp.sum(is_winner.astype(money.dtype), axis=1)    # [K]
    return new_owner, new_money, bought


# --------------------------------------------------------------------------
# AOT artifact registry — every entry becomes artifacts/<name>.hlo.txt
# --------------------------------------------------------------------------

def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def artifact_registry():
    """name -> (python callable, example arg specs).

    The rust runtime composes these: ``minplus_block_256`` is the unit tile
    the coordinator tiles arbitrary partition sizes with (block-sparse at
    L3); ``relax_while_*`` are fused whole-partition fixpoints for padded
    sizes; ``funding_step_*`` is a full DFEP round (steps 1+2) for the XLA
    engine.
    """
    return {
        "minplus_block_256": (
            lambda a, x: (minplus_mv(a, x),),
            [_spec((256, 256)), _spec((256,))],
        ),
        "minplus_mm_128": (
            lambda a, b: (minplus_mm(a, b, block_m=128, block_n=128,
                                     block_k=128),),
            [_spec((128, 128)), _spec((128, 128))],
        ),
        "relax_while_256": (
            lambda a, x: relax_while(a, x, max_steps=256),
            [_spec((256, 256)), _spec((256,))],
        ),
        "relax_while_1024": (
            lambda a, x: relax_while(a, x, max_steps=1024),
            [_spec((1024, 1024)), _spec((1024,))],
        ),
        "multi_relax_256x64": (
            lambda a, b: multi_relax_while(a, b, max_steps=256),
            [_spec((256, 256)), _spec((256, 64))],
        ),
        "funding_step_8_1024_4096": (
            funding_step,
            [_spec((4096,), jnp.int32), _spec((4096,), jnp.int32),
             _spec((4096,), jnp.int32), _spec((8, 1024))],
        ),
        "funding_step_32_4096_16384": (
            funding_step,
            [_spec((16384,), jnp.int32), _spec((16384,), jnp.int32),
             _spec((16384,), jnp.int32), _spec((32, 4096))],
        ),
    }
