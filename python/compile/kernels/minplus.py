"""Layer-1 Pallas kernels: tropical-semiring (min-plus) block products.

The ETSCH local-computation hot spot — distance relaxation and
connected-components label propagation inside one edge partition — is a
fixpoint of the tropical SpMV

    out[i] = min_j ( A[i, j] + x[j] )

over the partition's adjacency blocks (A[i,j] = w(i,j) for an edge, +inf
otherwise; w = 1 gives hop distances, w = 0 gives min-label spreading).

Hardware adaptation (see DESIGN.md §Hardware-Adaptation): the paper runs on
a Hadoop CPU cluster; on TPU the natural shape is dense VMEM tiles with a
(row-block, col-block) grid and a running-min accumulator — the tropical
analogue of a tiled matmul, executed on the VPU (the MXU has no min-plus
mode). BlockSpec expresses the HBM<->VMEM schedule; the rust coordinator
skips all-empty blocks (block-sparsity lives one level up).

All kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and correctness is what the artifact pipeline
validates. Real-TPU performance is *estimated* in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Value used as tropical "zero" (additive identity of min). Using a large
# finite value instead of +inf keeps the kernel total for integer dtypes and
# avoids inf-inf NaNs in padded blocks.
INF32 = jnp.float32(3.0e38) / 2


def _minplus_mv_kernel(a_ref, x_ref, o_ref):
    """One (bm, bn) tile of out[i] = min_j A[i,j] + x[j].

    Grid is (rows, cols); the column dimension is the reduction, so the
    output row-block is revisited across j with a running min.
    """
    j = pl.program_id(1)
    # (bm, bn) + (1, bn) -> (bm, bn); reduce the tile over its columns.
    partial = jnp.min(a_ref[...] + x_ref[...][None, :], axis=1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(j > 0)
    def _accum():
        o_ref[...] = jnp.minimum(o_ref[...], partial)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def minplus_mv(a: jax.Array, x: jax.Array, *, block_m: int = 256,
               block_n: int = 256) -> jax.Array:
    """Tropical matrix-vector product ``out[i] = min_j A[i,j] + x[j]``.

    ``a`` is (m, n), ``x`` is (n,); both dims must be multiples of the block
    sizes (the rust coordinator pads partitions with INF rows/cols).
    """
    m, n = a.shape
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    assert m % block_m == 0 and n % block_n == 0, (a.shape, block_m, block_n)
    grid = (m // block_m, n // block_n)
    return pl.pallas_call(
        _minplus_mv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((block_m,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), a.dtype),
        interpret=True,
    )(a, x)


def _minplus_mm_kernel(a_ref, b_ref, o_ref):
    """One (bm, bk) x (bk, bn) tile of out[i,l] = min_k A[i,k] + B[k,l]."""
    k = pl.program_id(2)
    # (bm, bk, 1) + (1, bk, bn) -> min over axis 1 -> (bm, bn)
    partial = jnp.min(a_ref[...][:, :, None] + b_ref[...][None, :, :], axis=1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(k > 0)
    def _accum():
        o_ref[...] = jnp.minimum(o_ref[...], partial)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def minplus_mm(a: jax.Array, b: jax.Array, *, block_m: int = 128,
               block_n: int = 128, block_k: int = 128) -> jax.Array:
    """Tropical matrix-matrix product ``out = A ⊗ B`` (min-plus semiring).

    Used for multi-source distance compression: columns of B are per-source
    distance vectors, so one ⊗ advances every source one sweep.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        _minplus_mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, l: (i, l)),
            pl.BlockSpec((block_k, block_n), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )(a, b)
