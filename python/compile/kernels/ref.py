"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

Everything here is deliberately the most literal possible transcription of
the math; no tiling, no tricks. pytest/hypothesis sweep shapes and dtypes
against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def minplus_mv_ref(a: jax.Array, x: jax.Array) -> jax.Array:
    """out[i] = min_j A[i,j] + x[j]."""
    return jnp.min(a + x[None, :], axis=1)


def minplus_mm_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """out[i,l] = min_k A[i,k] + B[k,l]."""
    return jnp.min(a[:, :, None] + b[None, :, :], axis=1)


def relax_ref(a: jax.Array, x: jax.Array, steps: int) -> jax.Array:
    """`steps` Bellman-Ford sweeps: x <- min(x, A ⊗ x)."""
    for _ in range(steps):
        x = jnp.minimum(x, minplus_mv_ref(a, x))
    return x
