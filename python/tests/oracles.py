"""Python-loop oracles for L2 model tests (deliberately naive)."""

from __future__ import annotations

import numpy as np


def funding_step_ref(src, dst, owner, money):
    """Literal per-vertex / per-edge transcription of DFEP Alg. 4 + 5 with
    the frontier-first rule (matching compile.model.funding_step and the
    rust engine): a vertex adjacent to at least one free edge bids only on
    free edges; otherwise it circulates across its own partition's edges.

    Conventions: owner -1 = free, -2 = padding; stranded vertex funding is
    kept on the vertex.
    """
    src = np.asarray(src)
    dst = np.asarray(dst)
    owner = np.asarray(owner).copy()
    money = np.asarray(money, dtype=np.float64).copy()
    k, v = money.shape
    e = len(src)

    deg_free = np.zeros(v)
    for idx in range(e):
        if owner[idx] == -1:
            deg_free[src[idx]] += 1
            deg_free[dst[idx]] += 1

    offers = np.zeros((k, e))
    contrib = np.zeros((k, e, 2))
    # --- step 1 (frontier-first) -------------------------------------------
    for i in range(k):
        deg_own = np.zeros(v)
        for idx in range(e):
            if owner[idx] == i:
                deg_own[src[idx]] += 1
                deg_own[dst[idx]] += 1
        share_free = np.zeros(v)
        share_own = np.zeros(v)
        for u in range(v):
            if deg_free[u] > 0:
                share_free[u] = money[i, u] / deg_free[u]
                money[i, u] = 0.0
            elif deg_own[u] > 0:
                share_own[u] = money[i, u] / deg_own[u]
                money[i, u] = 0.0
        for idx in range(e):
            if owner[idx] == -1:
                contrib[i, idx, 0] = share_free[src[idx]]
                contrib[i, idx, 1] = share_free[dst[idx]]
            elif owner[idx] == i:
                contrib[i, idx, 0] = share_own[src[idx]]
                contrib[i, idx, 1] = share_own[dst[idx]]
            offers[i, idx] = contrib[i, idx, 0] + contrib[i, idx, 1]
    # --- step 2 -------------------------------------------------------------
    bought = np.zeros(k)
    new_owner = owner.copy()
    for idx in range(e):
        if owner[idx] < -1:
            continue
        best = int(np.argmax(offers[:, idx]))
        if owner[idx] == -1 and offers[best, idx] >= 1.0:
            new_owner[idx] = best
            bought[best] += 1
            rem = (offers[best, idx] - 1.0) / 2
            money[best, src[idx]] += rem
            money[best, dst[idx]] += rem
            for i in range(k):
                if i != best:
                    money[i, src[idx]] += contrib[i, idx, 0]
                    money[i, dst[idx]] += contrib[i, idx, 1]
        else:
            for i in range(k):
                if owner[idx] == i:
                    money[i, src[idx]] += offers[i, idx] / 2
                    money[i, dst[idx]] += offers[i, idx] / 2
                else:
                    money[i, src[idx]] += contrib[i, idx, 0]
                    money[i, dst[idx]] += contrib[i, idx, 1]
    return new_owner, money, bought


def sssp_ref(n: int, edges, source: int):
    """BFS hop distances on an unweighted undirected graph."""
    from collections import deque

    adj = [[] for _ in range(n)]
    for (u, v) in edges:
        adj[u].append(v)
        adj[v].append(u)
    dist = [float("inf")] * n
    dist[source] = 0
    q = deque([source])
    while q:
        u = q.popleft()
        for w in adj[u]:
            if dist[w] == float("inf"):
                dist[w] = dist[u] + 1
                q.append(w)
    return dist
