"""AOT pipeline tests: every registry entry lowers to valid HLO text and
the manifest describes its interface correctly.

(The execution side of the interchange — HLO text -> PJRT compile -> run —
is covered by the rust integration tests; here we validate the producer.)
"""

import json

import jax
import numpy as np
import pytest

from compile.aot import to_hlo_text, _dtype_name
from compile.model import artifact_registry


@pytest.fixture(scope="module")
def registry():
    return artifact_registry()


def test_registry_is_nonempty_and_named(registry):
    assert len(registry) >= 6
    for name in registry:
        assert name.replace("_", "").isalnum(), name


@pytest.mark.parametrize("name", list(artifact_registry()))
def test_every_entry_lowers_to_hlo_text(name, registry):
    fn, specs = registry[name]
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule"), text[:60]
    assert "ENTRY" in text
    # interchange requirement: parsable text, not a serialized proto
    assert "\x00" not in text


@pytest.mark.parametrize("name", list(artifact_registry()))
def test_lowered_function_executes_and_matches_eval_shape(name, registry):
    fn, specs = registry[name]
    rng = np.random.default_rng(0)
    args = []
    for s in specs:
        if s.dtype == np.int32:
            args.append(
                rng.integers(-1, 8, size=s.shape).astype(np.int32))
        else:
            args.append(
                rng.uniform(0, 4, size=s.shape).astype(np.float32))
    out = jax.jit(fn)(*args)
    shapes = jax.eval_shape(fn, *specs)
    flat_out = jax.tree_util.tree_leaves(out)
    flat_shapes = jax.tree_util.tree_leaves(shapes)
    assert len(flat_out) == len(flat_shapes)
    for got, want in zip(flat_out, flat_shapes):
        assert got.shape == want.shape
        assert got.dtype == want.dtype


def test_manifest_written_and_consistent(tmp_path, registry):
    from compile import aot
    import sys

    argv = sys.argv
    sys.argv = ["aot", "--out-dir", str(tmp_path),
                "--only", "minplus_block_256"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert "minplus_block_256" in manifest
    entry = manifest["minplus_block_256"]
    assert (tmp_path / entry["file"]).exists()
    assert entry["inputs"][0] == {"shape": [256, 256], "dtype": "f32"}
    assert entry["outputs"][0] == {"shape": [256], "dtype": "f32"}


def test_dtype_names():
    import numpy as np
    assert _dtype_name(np.dtype("float32")) == "f32"
    assert _dtype_name(np.dtype("int32")) == "i32"
