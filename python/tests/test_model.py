"""L2 correctness: relaxation fixpoint vs BFS; funding_step vs loop oracle.

The funding tests are the python half of the DFEP cross-validation — the
rust engine re-implements the same round semantics and is checked against
the same invariants (rust/src/partition/dfep.rs tests).
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.minplus import INF32
from compile.model import funding_step, relax_step, relax_while, \
    multi_source_step
from tests.oracles import funding_step_ref, sssp_ref

INF = float(INF32)


def _random_graph(rng, n, m):
    """m distinct undirected edges over n vertices (may be disconnected)."""
    edges = set()
    while len(edges) < m:
        u, v = rng.integers(0, n, 2)
        if u != v:
            edges.add((min(u, v), max(u, v)))
    return sorted(edges)


def _dense(n, edges, w=1.0):
    a = np.full((n, n), INF, np.float32)
    for u, v in edges:
        a[u, v] = w
        a[v, u] = w
    return a


# ----------------------------------------------------------- relaxation

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_relax_while_equals_bfs(seed):
    rng = np.random.default_rng(seed)
    n = 32
    edges = _random_graph(rng, n, 48)
    a = _dense(n, edges)
    src = int(rng.integers(0, n))
    x0 = np.full((n,), INF, np.float32)
    x0[src] = 0.0
    got, steps = relax_while(jnp.asarray(a), jnp.asarray(x0), n)
    want = sssp_ref(n, edges, src)
    for i in range(n):
        if want[i] == float("inf"):
            assert got[i] >= INF / 2
        else:
            assert got[i] == want[i]
    assert 0 < int(steps) <= n


def test_relax_step_idempotent_at_fixpoint():
    rng = np.random.default_rng(3)
    n = 16
    edges = _random_graph(rng, n, 30)
    a = jnp.asarray(_dense(n, edges))
    x = np.full((n,), INF, np.float32)
    x[0] = 0.0
    x, _ = relax_while(a, jnp.asarray(x), n)
    again = relax_step(a, x)
    np.testing.assert_array_equal(np.asarray(again), np.asarray(x))


def test_connected_components_via_zero_weights():
    """w=0 adjacency turns relaxation into min-label spreading."""
    # two components: {0,1,2}, {3,4}
    edges = [(0, 1), (1, 2), (3, 4)]
    a = _dense(8, edges, w=0.0)
    labels = np.arange(8, dtype=np.float32) + 10.0
    out, _ = relax_while(jnp.asarray(a), jnp.asarray(labels), 8)
    out = np.asarray(out)
    assert out[0] == out[1] == out[2] == 10.0
    assert out[3] == out[4] == 13.0
    assert (out[5:] == labels[5:]).all()      # isolated vertices keep labels


def test_multi_source_step_matches_single_source():
    rng = np.random.default_rng(7)
    n = 32
    edges = _random_graph(rng, n, 64)
    a = jnp.asarray(_dense(n, edges))
    b = np.full((n, n), INF, np.float32)
    np.fill_diagonal(b, 0.0)
    b = jnp.asarray(b)
    for _ in range(3):
        b = multi_source_step(a, b)
    for s in [0, 5, 31]:
        x = np.full((n,), INF, np.float32)
        x[s] = 0.0
        x = jnp.asarray(x)
        for _ in range(3):
            x = relax_step(a, x)
        np.testing.assert_array_equal(np.asarray(b)[:, s], np.asarray(x))


# ----------------------------------------------------------- funding round

def _random_funding_instance(rng, k, n, m, owned_frac):
    edges = _random_graph(rng, n, m)
    e = len(edges)
    src = np.array([u for u, _ in edges], np.int32)
    dst = np.array([v for _, v in edges], np.int32)
    owner = np.full((e,), -1, np.int32)
    owned = rng.uniform(size=e) < owned_frac
    owner[owned] = rng.integers(0, k, owned.sum())
    # a few padding entries at the tail
    pad = max(1, e // 8)
    src = np.concatenate([src, np.zeros(pad, np.int32)])
    dst = np.concatenate([dst, np.zeros(pad, np.int32)])
    owner = np.concatenate([owner, np.full(pad, -2, np.int32)])
    money = rng.uniform(0, 4, (k, n)).astype(np.float32)
    return src, dst, owner, money


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000),
       owned_frac=st.sampled_from([0.0, 0.3, 0.8]))
def test_funding_step_matches_oracle(seed, owned_frac):
    rng = np.random.default_rng(seed)
    src, dst, owner, money = _random_funding_instance(rng, 4, 24, 40,
                                                      owned_frac)
    no, nm, b = funding_step(jnp.asarray(src), jnp.asarray(dst),
                             jnp.asarray(owner), jnp.asarray(money))
    ro, rm, rb = funding_step_ref(src, dst, owner, money)
    np.testing.assert_array_equal(np.asarray(no), ro)
    np.testing.assert_allclose(np.asarray(nm), rm, rtol=1e-3, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(b), rb)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_funding_conservation(seed):
    """money_after + edges_bought == money_before (1 unit pays 1 edge)."""
    rng = np.random.default_rng(seed)
    src, dst, owner, money = _random_funding_instance(rng, 6, 32, 56, 0.2)
    no, nm, b = funding_step(jnp.asarray(src), jnp.asarray(dst),
                             jnp.asarray(owner), jnp.asarray(money))
    before = float(np.asarray(money, np.float64).sum())
    after = float(np.asarray(nm, np.float64).sum()) + float(np.asarray(b).sum())
    np.testing.assert_allclose(after, before, rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_funding_owner_monotone(seed):
    """Owned edges never change hands; padding never gets sold (plain DFEP)."""
    rng = np.random.default_rng(seed)
    src, dst, owner, money = _random_funding_instance(rng, 4, 24, 40, 0.5)
    no, _, _ = funding_step(jnp.asarray(src), jnp.asarray(dst),
                            jnp.asarray(owner), jnp.asarray(money))
    no = np.asarray(no)
    assigned = owner >= 0
    np.testing.assert_array_equal(no[assigned], owner[assigned])
    np.testing.assert_array_equal(no[owner == -2], owner[owner == -2])
    # a sold edge goes to a real partition
    assert ((no >= -2) & (no < 4)).all()


def test_funding_no_money_no_sale():
    src = np.array([0, 1], np.int32)
    dst = np.array([1, 2], np.int32)
    owner = np.array([-1, -1], np.int32)
    money = np.zeros((3, 4), np.float32)
    no, nm, b = funding_step(jnp.asarray(src), jnp.asarray(dst),
                             jnp.asarray(owner), jnp.asarray(money))
    assert (np.asarray(no) == -1).all()
    assert np.asarray(nm).sum() == 0.0
    assert np.asarray(b).sum() == 0.0


def test_funding_single_bidder_expands_region():
    """One partition with ample funds buys all its frontier edges."""
    # triangle 0-1-2 plus tail 2-3; partition 0 funded at vertex 0
    src = np.array([0, 0, 1, 2], np.int32)
    dst = np.array([1, 2, 2, 3], np.int32)
    owner = np.full((4,), -1, np.int32)
    money = np.zeros((2, 4), np.float32)
    money[0, 0] = 10.0
    no, nm, b = funding_step(jnp.asarray(src), jnp.asarray(dst),
                             jnp.asarray(owner), jnp.asarray(money))
    no = np.asarray(no)
    # vertex 0's two incident edges get 5 units each -> both sold to p0
    np.testing.assert_array_equal(no, [0, 0, -1, -1])
    assert float(np.asarray(b)[0]) == 2.0
