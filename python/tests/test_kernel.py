"""L1 correctness: Pallas min-plus kernels vs the pure-jnp oracle.

Hypothesis sweeps block-grid shapes, value ranges (including tropical-INF
padding) and dtypes; results must match the oracle exactly (min and + are
evaluated in an order-independent way, so no float slack is needed for
f32 inputs drawn from a finite range).
"""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.minplus import INF32, minplus_mv, minplus_mm
from compile.kernels.ref import minplus_mv_ref, minplus_mm_ref, relax_ref

RNG = np.random.default_rng(42)


def _rand(shape, inf_frac=0.0, dtype=np.float32):
    a = RNG.uniform(0.0, 100.0, shape).astype(dtype)
    if inf_frac > 0:
        mask = RNG.uniform(size=shape) < inf_frac
        a = np.where(mask, np.asarray(float(INF32), dtype), a)
    return a


# ---------------------------------------------------------------- mv kernel

@settings(max_examples=40, deadline=None)
@given(
    mb=st.integers(1, 4), nb=st.integers(1, 4),
    block=st.sampled_from([8, 16, 32]),
    inf_frac=st.sampled_from([0.0, 0.3, 0.9]),
)
def test_mv_matches_ref(mb, nb, block, inf_frac):
    a = _rand((mb * block, nb * block), inf_frac)
    x = _rand((nb * block,), inf_frac)
    got = minplus_mv(a, x, block_m=block, block_n=block)
    want = minplus_mv_ref(a, x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_mv_default_block_256():
    a = _rand((512, 256), 0.5)
    x = _rand((256,))
    np.testing.assert_array_equal(
        np.asarray(minplus_mv(a, x)), np.asarray(minplus_mv_ref(a, x)))


def test_mv_rectangular_blocks():
    a = _rand((64, 96), 0.2)
    x = _rand((96,))
    got = minplus_mv(a, x, block_m=32, block_n=16)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(minplus_mv_ref(a, x)))


def test_mv_all_inf_column_is_inert():
    """A padded (all-INF) column never wins the min."""
    a = _rand((32, 32))
    a[:, 7] = float(INF32)
    x = _rand((32,))
    x[7] = 0.0
    got = np.asarray(minplus_mv(a, x, block_m=16, block_n=16))
    a2 = np.delete(a, 7, axis=1)
    x2 = np.delete(x, 7)
    np.testing.assert_array_equal(got, np.asarray(minplus_mv_ref(a2, x2)))


def test_mv_identity_of_min():
    """A with 0 diagonal and INF off-diagonal is the tropical identity."""
    n = 64
    a = np.full((n, n), float(INF32), np.float32)
    np.fill_diagonal(a, 0.0)
    x = _rand((n,))
    got = np.asarray(minplus_mv(a, x, block_m=32, block_n=32))
    np.testing.assert_array_equal(got, x)


def test_mv_bad_shape_asserts():
    a = _rand((100, 100))
    x = _rand((100,))
    with pytest.raises(AssertionError):
        minplus_mv(a, x, block_m=64, block_n=64)


# ---------------------------------------------------------------- mm kernel

@settings(max_examples=25, deadline=None)
@given(
    mb=st.integers(1, 3), nb=st.integers(1, 3), kb=st.integers(1, 3),
    block=st.sampled_from([8, 16]),
    inf_frac=st.sampled_from([0.0, 0.4]),
)
def test_mm_matches_ref(mb, nb, kb, block, inf_frac):
    a = _rand((mb * block, kb * block), inf_frac)
    b = _rand((kb * block, nb * block), inf_frac)
    got = minplus_mm(a, b, block_m=block, block_n=block, block_k=block)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(minplus_mm_ref(a, b)))


def test_mm_default_block_128():
    a = _rand((128, 256), 0.5)
    b = _rand((256, 128), 0.5)
    np.testing.assert_array_equal(
        np.asarray(minplus_mm(a, b)), np.asarray(minplus_mm_ref(a, b)))


def test_mm_associativity_with_identity():
    """(A ⊗ I) == A in the tropical semiring."""
    n = 32
    a = _rand((n, n), 0.3)
    ident = np.full((n, n), float(INF32), np.float32)
    np.fill_diagonal(ident, 0.0)
    got = np.asarray(minplus_mm(a, ident, block_m=16, block_n=16, block_k=16))
    np.testing.assert_array_equal(got, a)


def test_mm_agrees_with_mv_per_column():
    a = _rand((64, 64), 0.2)
    b = _rand((64, 32), 0.2)
    mm = np.asarray(minplus_mm(a, b, block_m=32, block_n=32, block_k=32))
    for c in range(b.shape[1]):
        mv = np.asarray(minplus_mv(a, jnp.asarray(b[:, c]),
                                   block_m=32, block_n=32))
        np.testing.assert_array_equal(mm[:, c], mv)


# ------------------------------------------------------- semiring properties

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_mv_monotone(seed):
    """x' <= x pointwise implies A ⊗ x' <= A ⊗ x pointwise."""
    rng = np.random.default_rng(seed)
    a = rng.uniform(0, 50, (32, 32)).astype(np.float32)
    x = rng.uniform(0, 50, (32,)).astype(np.float32)
    x2 = x - rng.uniform(0, 5, (32,)).astype(np.float32)
    y = np.asarray(minplus_mv(a, x, block_m=16, block_n=16))
    y2 = np.asarray(minplus_mv(a, x2, block_m=16, block_n=16))
    assert (y2 <= y + 1e-5).all()


def test_relax_ref_converges_on_path():
    """Sanity for the oracle itself: path graph distances."""
    n = 16
    a = np.full((n, n), float(INF32), np.float32)
    for i in range(n - 1):
        a[i, i + 1] = 1.0
        a[i + 1, i] = 1.0
    x = np.full((n,), float(INF32), np.float32)
    x[0] = 0.0
    out = np.asarray(relax_ref(a, x, n))
    np.testing.assert_array_equal(out, np.arange(n, dtype=np.float32))
